"""Count-sketch compression (FetchSGD [66], Count-Sketch optimizer [74]).

The sketch S is an (r, c) array; coordinate i of the input lands in bucket
``h_j(i)`` of every row j with sign ``s_j(i)``, both from universal hashing.
Unsketching estimates x_i as the *median* over rows of ``s_j(i) * S[j, h_j(i)]``
(median-of-means heavy-hitter recovery).

Crucially the sketch is **linear**: sketch(Σ_c g_c) = Σ_c sketch(g_c), which is
what lets FetchSGD aggregate client sketches server-side by plain summation —
on the TPU mesh this means the all-gather payload is the (r, c) sketch, not
the d-dimensional gradient.

TPU adaptation (see DESIGN.md): scatter-add is hash → one-hot → matmul, which
maps the accumulation onto the MXU instead of a serial scatter unit. The
Pallas kernel (``repro.kernels.count_sketch``) implements exactly that; this
module holds the pure-JAX reference implementation used inside the FL step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compress.api import CommTransform, register, register_stage

def hash_params(rows: int, seed: int = 17):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    # odd multipliers -> multiplicative hashing over Z/2^32 (uint32 wraparound)
    a = jax.random.randint(ks[0], (rows,), 1, 1 << 30, dtype=jnp.int32) * 2 + 1
    b = jax.random.randint(ks[1], (rows,), 0, 1 << 30, dtype=jnp.int32)
    return a.astype(jnp.uint32), b.astype(jnp.uint32)


def bucket_and_sign(i, a, b, cols):
    """i: (n,) indices; a,b: (r,) uint32. Returns h (r,n) buckets, s (r,n) signs."""
    ab = a[:, None] * i[None, :].astype(jnp.uint32) + b[:, None]   # mod 2^32
    h = (ab % jnp.uint32(cols)).astype(jnp.int32)
    s = jnp.where((ab // jnp.uint32(cols)) % 2 == 0, 1.0, -1.0).astype(jnp.float32)
    return h, s


def sketch(x, rows, cols, seed=17):
    n = x.shape[0]
    a, b = hash_params(rows, seed)
    h, s = bucket_and_sign(jnp.arange(n, dtype=jnp.int32), a, b, cols)
    sx = s * x.astype(jnp.float32)[None, :]                      # (r, n)
    S = jax.vmap(lambda hv, v: jnp.zeros(cols, jnp.float32).at[hv].add(v))(h, sx)
    return S


def unsketch(S, n, seed=17):
    rows, cols = S.shape
    a, b = hash_params(rows, seed)
    h, s = bucket_and_sign(jnp.arange(n, dtype=jnp.int32), a, b, cols)
    est = s * jax.vmap(lambda Sr, hv: Sr[hv])(S, h)              # (r, n)
    return jnp.median(est, axis=0)


class CountSketch(CommTransform):
    """FetchSGD-style sketch; top-k heavy hitters recovered on decode.

    The sketch width adapts to the leaf size (rows*cols <= n/2) so the wire
    always beats dense f32 — FetchSGD sketches the whole gradient at a fixed
    compression ratio; leaf-wise operation needs the same scaling.

    The flattened sketch is the carrier, so a quantizer can refine it:
    ``"sketch>>qsgd:8"`` puts int8 sketch buckets on the wire.

    ``backend="kernel"``: the hash-scatter runs as the one-hot-MXU Pallas
    kernel. Bucket sums accumulate in a different order (per-CHUNK matmul
    partials vs one scatter-add), so parity vs pure JAX is bounded-ULP on
    S, not bit-exact (DESIGN.md §6)."""
    biased = True
    carrier_key = "S"
    kernel_capable = True

    def __init__(self, rows=5, cols=4096, topk_fraction=0.01, seed=17,
                 backend="jax"):
        self.rows, self.cols, self.seed = rows, cols, seed
        self.topk_fraction = topk_fraction
        self.backend = backend
        self.name = f"sketch{rows}x{cols}" + \
            ("@kernel" if backend == "kernel" else "")

    def _cols(self, n):
        return int(min(self.cols, max(8, n // (2 * self.rows))))

    def encode(self, state, rng, x):
        if self.backend == "kernel":
            from repro.kernels import ops
            S = ops.sketch(x, self.rows, self._cols(x.shape[0]), self.seed)
        else:
            S = sketch(x, self.rows, self._cols(x.shape[0]), self.seed)
        return {"S": S.reshape(-1)}, state

    def decode(self, payload, n):
        S = payload["S"].reshape(self.rows, self._cols(n))
        est = unsketch(S, n, self.seed)
        k = max(1, int(round(n * self.topk_fraction)))
        _, idx = jax.lax.top_k(jnp.abs(est), k)
        out = jnp.zeros((n,), jnp.float32)
        return out.at[idx].set(est[idx])

    def carrier_len(self, n):
        return self.rows * self._cols(n)

    def meta_bits(self, n):
        return 0.0


register("sketch")(lambda rows=5, cols=4096, fraction=0.01, backend="jax",
                   **kw: CountSketch(rows, cols, fraction, backend=backend))
register_stage("sketch")(lambda r=None, c=None, rows=5, cols=4096,
                         fraction=0.01, backend="jax", **kw:
                         CountSketch(int(r or rows), int(c or cols), fraction,
                                     backend=backend))
