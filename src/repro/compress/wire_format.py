"""Packed wire formats — int codes on the wire (DESIGN.md §10).

The staged pipeline ships each stage's payload in its *storage* dtype: int8
signs (8 bits for a ternary symbol), int8 QSGD levels (8 bits for a 4-bit
code). The ledger already reported the packed cost via ``entropy_bits``, but
the collective moved the wide buffers — the compression win lived in
accounting, not on the link. This module makes the packed form the payload
itself, so the ``all_gather`` operand IS the wire format and the HLO
collective bytes equal the ledger's ``wire_bits / 8`` exactly.

Byte layouts (little-endian within the byte; DESIGN.md §10):

  * ``pack2``  — 2-bit two's-complement codes, 4 per byte:
                 ``byte = c0 | c1<<2 | c2<<4 | c3<<6``; code -1 -> 0b11,
                 0 -> 0b00, +1 -> 0b01.  Length ``ceil(n/4)``; the tail
                 byte's unused fields are zero.
  * ``pack4``  — 4-bit two's-complement codes (range [-8, 7]), 2 per byte:
                 ``byte = c0 | c1<<4``.  Length ``ceil(n/2)``.  QSGD at
                 ``bits <= 4`` has levels in [-7, 7], so nibble packing is
                 lossless; ``bits > 4`` cannot pack and fails loudly.

Both pack the FLAT code vector.  Because every blocked kernel layout uses a
block length divisible by 4, byte ``i`` of the flat packing covers codes
``4i..4i+3`` in blocked layouts too — the Pallas fused pack kernels
(``repro.kernels.bitpack``) emit bit-identical bytes row by row, and the
flattened, sliced kernel output equals the pure-JAX flat packing exactly
(tests/test_kernel_parity.py round-trip cases).

``payload_nbytes`` sizes a pipeline's payload by ``jax.eval_shape`` — the
ground truth the ledger is tested against for every packable spec.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# wire formats a pipeline stage may ship: "staged" keeps the historical
# storage-dtype payloads (bit-exact with every pre-packing engine); "packed"
# ships the bit-packed codes (the "@fused" spec suffix / FLConfig.wire_format)
WIRE_FORMATS = ("staged", "packed")


def check_wire_format(wire: str) -> str:
    if wire not in WIRE_FORMATS:
        raise ValueError(
            f"unknown wire format {wire!r}; have {WIRE_FORMATS}")
    return wire


def packed_len(n: int, bits: int) -> int:
    """Bytes needed for n codes at ``bits`` bits per code (2 or 4)."""
    per = 8 // bits
    return -(-n // per)


def pack2(codes: jax.Array) -> jax.Array:
    """int8 ternary codes (n,) in {-1, 0, +1} -> uint8 (ceil(n/4),)."""
    n = codes.shape[0]
    pad = (-n) % 4
    u = (jnp.pad(codes, (0, pad)).astype(jnp.uint8) & 3).reshape(-1, 4)
    return (u[:, 0] | (u[:, 1] << 2) | (u[:, 2] << 4)
            | (u[:, 3] << 6)).astype(jnp.uint8)


def unpack2(packed: jax.Array, n: int) -> jax.Array:
    """uint8 (ceil(n/4),) -> int8 codes (n,) (2-bit sign extension)."""
    u = (packed[:, None] >> jnp.array([0, 2, 4, 6], jnp.uint8)) & 3
    c = ((u + 2) & 3).astype(jnp.int8) - 2
    return c.reshape(-1)[:n]


def pack4(codes: jax.Array) -> jax.Array:
    """int8 codes (n,) in [-8, 7] -> uint8 (ceil(n/2),), low nibble first."""
    n = codes.shape[0]
    pad = (-n) % 2
    u = (jnp.pad(codes, (0, pad)).astype(jnp.uint8) & 15).reshape(-1, 2)
    return (u[:, 0] | (u[:, 1] << 4)).astype(jnp.uint8)


def unpack4(packed: jax.Array, n: int) -> jax.Array:
    """uint8 (ceil(n/2),) -> int8 codes (n,) (4-bit sign extension)."""
    u = (packed[:, None] >> jnp.array([0, 4], jnp.uint8)) & 15
    c = ((u + 8) & 15).astype(jnp.int8) - 8
    return c.reshape(-1)[:n]


def payload_nbytes(pipe, n: int) -> int:
    """Exact bytes of ``pipe``'s encoded payload for a length-n leaf, via
    ``jax.eval_shape`` (no FLOPs).  This is what the aggregation collective
    gathers per client — for packable specs the ledger's ``wire_bits(n)``
    must equal ``8 * payload_nbytes`` (tests/test_kernel_parity.py)."""
    state = jax.eval_shape(lambda: pipe.init((n,)))
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    x = jax.ShapeDtypeStruct((n,), jnp.float32)
    payload, _ = jax.eval_shape(pipe.encode, state, rng, x)
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(payload))
