from repro.compress.api import (CommTransform, Compressor, Identity,
                                make_compressor, make_pipeline)
from repro.compress.pipeline import (chain, error_feedback,
                                     momentum_correction)
from repro.compress import quantization, sparsification, sketch  # registers
from repro.compress.secure_agg import DPNoise, SecAgg  # privacy stages (§11)

__all__ = ["CommTransform", "Compressor", "Identity", "chain",
           "error_feedback", "momentum_correction", "make_compressor",
           "make_pipeline", "SecAgg", "DPNoise"]
