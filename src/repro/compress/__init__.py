from repro.compress.api import Compressor, Identity, make_compressor
from repro.compress import quantization, sparsification, sketch  # registers

__all__ = ["Compressor", "Identity", "make_compressor"]
