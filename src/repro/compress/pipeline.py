"""Pipeline combinators for the CommTransform protocol (DESIGN.md §2).

``chain(a, b, ...)`` composes stages along each stage's *carrier*: stage i's
``payload[carrier_key]`` is re-encoded by stage i+1 instead of travelling as
f32.  Reconstruction runs the stages backwards, substituting each refined
carrier before the outer decode.  Because only the shrinking carrier is
re-encoded (side info like indices/scales is kept at each stage), wire bits
compose multiplicatively: ``chain(topk(0.01), qsgd(8))`` pays top-k's index
bits on k = 0.01·n coordinates plus QSGD's 8 bits on those k values.

``error_feedback(t)`` / ``momentum_correction(t)`` are *wrapping* transforms
(EF-SGD / DGC): they own the residual / momentum state that previously lived
in ``FLState.ef_residual`` and the trainer, and expose the same protocol, so
the aggregation layer threads state generically with no special cases.

State contract (DESIGN.md §2): every array returned by ``init(shape)`` is
zero-initialised and either leaf-shaped (shards like the parameter it
accompanies) or small; wrappers reshape leaf-shaped state to the flat
working vector internally, so they compose with any inner pipeline.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.api import CommTransform, Identity

__all__ = ["Chain", "chain", "ErrorFeedback", "error_feedback",
           "MomentumCorrection", "momentum_correction",
           "stage_sequence", "stage_input_lens"]


class Chain(CommTransform):
    """Sequential composition of stages along their carriers."""

    carrier_key = None          # chains are not themselves chainable stages

    def __init__(self, *stages: CommTransform):
        assert len(stages) >= 2, "use chain(...) — it handles 0/1 stages"
        for s in stages[:-1]:
            if s.carrier_key is None:
                raise ValueError(
                    f"stage {s.name!r} is terminal (no carrier) and cannot "
                    f"be followed by another stage")
        self.stages: Tuple[CommTransform, ...] = tuple(stages)
        self.name = ">>".join(s.name for s in stages)

    @property
    def biased(self):
        return any(s.biased for s in self.stages)

    @property
    def kernel_capable(self):
        return all(s.kernel_capable for s in self.stages)

    def _lens(self, n):
        """Input length seen by each stage: n, then the carrier lengths."""
        ms = [n]
        for s in self.stages[:-1]:
            ms.append(s.carrier_len(ms[-1]))
        return ms

    # --- state -------------------------------------------------------------
    def init(self, shape):
        n = int(np.prod(shape))
        ms = self._lens(n)
        return tuple(s.init(tuple(shape) if i == 0 else (ms[i],))
                     for i, s in enumerate(self.stages))

    # --- wire maps ---------------------------------------------------------
    def encode(self, state, rng, x):
        payload, new_states, cur = {}, [], x
        last = len(self.stages) - 1
        for i, s in enumerate(self.stages):
            p, st = s.encode(state[i], jax.random.fold_in(rng, i), cur)
            new_states.append(st)
            if i < last:
                p = dict(p)
                cur = p.pop(s.carrier_key)
            payload[f"s{i}"] = p
        return payload, tuple(new_states)

    def decode(self, payload, n):
        ms = self._lens(n)
        last = len(self.stages) - 1
        cur = self.stages[last].decode(payload[f"s{last}"], ms[last])
        for i in range(last - 1, -1, -1):
            p = dict(payload[f"s{i}"])
            p[self.stages[i].carrier_key] = cur
            cur = self.stages[i].decode(p, ms[i])
        return cur

    # --- byte accounting ----------------------------------------------------
    def carrier_len(self, n):
        return self.stages[-1].carrier_len(self._lens(n)[-1])

    def meta_bits(self, n):
        return sum(s.meta_bits(m) for s, m in zip(self.stages, self._lens(n)))

    def dp_rho_per_round(self):
        return sum(s.dp_rho_per_round() for s in self.stages)

    def meta_entropy_bits(self, n):
        # carrier-conditional composition (DESIGN.md §1): each stage's
        # entropy estimate is conditioned on the *distribution* of the
        # carrier it receives (e.g. qsgd levels on a top-k carrier are
        # large, where Elias-gamma is expensive), not just its length
        total, hint = 0.0, None
        for s, m in zip(self.stages, self._lens(n)):
            total += s.meta_entropy_bits_given(m, hint)
            hint = s.carrier_hint(m)
        return total


def stage_sequence(pipe: CommTransform) -> Tuple[CommTransform, ...]:
    """The carrier stage sequence under any wrappers — the flight recorder's
    per-stage attribution axis (repro.obs.telemetry, DESIGN.md §12).

    Wrappers (EF / DGC momentum, SecAgg, DPNoise) all delegate their byte
    accounting to ``.inner`` (``meta_bits(n) == inner.wire_bits(n)``, no
    carrier of their own), so unwrapping them and decomposing the innermost
    chain reproduces the wrapped pipeline's ``wire_bits`` exactly."""
    while hasattr(pipe, "inner"):
        pipe = pipe.inner
    return tuple(pipe.stages) if isinstance(pipe, Chain) else (pipe,)


def stage_input_lens(stages, n):
    """Input length each stage of a carrier sequence sees for an n-length
    leaf: ``n``, then the preceding carrier lengths (``Chain._lens``)."""
    ms = [n]
    for s in stages[:-1]:
        ms.append(s.carrier_len(ms[-1]))
    return ms


def chain(*transforms: CommTransform) -> CommTransform:
    """Compose transforms; Identity is the unit, a single stage is itself."""
    flat = []
    for t in transforms:
        if isinstance(t, Chain):
            flat.extend(t.stages)
        elif t.is_identity:
            continue
        else:
            flat.append(t)
    if not flat:
        return Identity()
    if len(flat) == 1:
        return flat[0]
    return Chain(*flat)


# ---------------------------------------------------------------------------
# Wrapping transforms — stateful correction schemes as pipeline stages
# ---------------------------------------------------------------------------

class _Wrapper(CommTransform):
    """Shared plumbing: decode and byte accounting delegate to the inner
    pipeline (corrections change *what* is encoded, not the wire format)."""

    biased = False              # the wrapper is the bias correction
    carrier_key = None          # wrappers are outermost, not chainable stages

    def __init__(self, inner: CommTransform):
        self.inner = inner

    def decode(self, payload, n):
        return self.inner.decode(payload, n)

    def meta_bits(self, n):
        return self.inner.wire_bits(n)

    def meta_entropy_bits(self, n):
        return self.inner.entropy_bits(n)

    def dp_rho_per_round(self):
        return self.inner.dp_rho_per_round()


class ErrorFeedback(_Wrapper):
    """EF-SGD (Karimireddy et al. 2019; the survey's biased-compressor fix):
    encode x + e, keep e' = (x + e) − decode(encode(x + e)) locally."""

    def __init__(self, inner: CommTransform, decay: float = 1.0):
        super().__init__(inner)
        self.decay = decay
        self.name = f"ef({inner.name})"

    def init(self, shape):
        return {"residual": jnp.zeros(shape, jnp.float32),
                "inner": self.inner.init(shape)}

    def encode(self, state, rng, x):
        y = x + self.decay * state["residual"].reshape(x.shape)
        payload, ist = self.inner.encode(state["inner"], rng, y)
        # local decode of our own payload: one extra O(n) dequantize per leaf
        # vs. an aggregator that reuses its post-gather decode — the price of
        # keeping correction state out of the aggregation layer entirely
        y_hat = self.inner.decode(payload, y.shape[0])
        res = (y - y_hat).reshape(state["residual"].shape)
        return payload, {"residual": res, "inner": ist}


class MomentumCorrection(_Wrapper):
    """DGC (Lin et al. 2018) momentum correction + gradient accumulation:
    u ← m·u + x; v ← v + u; transmit encode(v); the unsent part of v stays
    local and the momentum of *sent* coordinates is cleared (masking).

    Warm-up sparsity schedule (DGC §3.3): with ``warmup_rounds = W`` and
    ``final_fraction = f``, round r transmits the top ``f^((r+1)/(W+1))``
    fraction — exponentially annealing from nearly-dense to the target.
    Shapes stay static under jit: the *inner* pipeline is sized for the
    first (widest) round's fraction and later rounds mask ``v`` down to the
    annealed effective support before encoding, so the extra slots carry
    zeros. The wire payload (and ``wire_bits``) is therefore constant at
    the warm-up capacity; the *effective* sparsity anneals."""

    def __init__(self, inner: CommTransform, momentum: float = 0.9,
                 warmup_rounds: int = 0, final_fraction: float = 0.0):
        super().__init__(inner)
        self.momentum = momentum
        self.warmup_rounds = int(warmup_rounds)
        self.final_fraction = final_fraction
        self.name = f"mc{momentum:g}({inner.name})"
        if self.warmup_rounds:
            assert 0.0 < final_fraction <= 1.0, \
                "warm-up schedule needs the target (final) fraction"
            self.name += f"@warmup{self.warmup_rounds}"

    def init(self, shape):
        st = {"u": jnp.zeros(shape, jnp.float32),
              "v": jnp.zeros(shape, jnp.float32),
              "inner": self.inner.init(shape)}
        if self.warmup_rounds:
            st["round"] = jnp.zeros((), jnp.int32)
        return st

    def _anneal_mask(self, v, rounds):
        """Zero all but the top-k_eff coordinates of v, where the effective
        fraction f_r = final^((r+1)/(W+1)) anneals down to final.

        k_eff is traced (``rounds`` is carried state) but bounded by the
        schedule's STATIC round-0 fraction final^(1/(W+1)), so one
        ``lax.top_k`` over that widest prefix replaces a full sort and the
        order statistic is gathered from the prefix — the same
        construction as ``kernels.ops.stc_ternarize(max_fraction=...)``."""
        n = v.shape[0]
        expo = jnp.minimum(rounds + 1, self.warmup_rounds + 1) / \
            (self.warmup_rounds + 1.0)
        frac = jnp.exp(expo * jnp.log(self.final_fraction))
        k_eff = jnp.clip(jnp.round(n * frac).astype(jnp.int32), 1, n)
        f_max = self.final_fraction ** (1.0 / (self.warmup_rounds + 1.0))
        k_max = max(1, min(int(round(n * f_max)), n))
        # masked min, not a gather: a slice/gather fused into top_k's
        # output defeats XLA's TopkRewriter (full-sort fallback) — see
        # kernels.ops._stc_threshold
        prefix = jax.lax.top_k(jnp.abs(v), k_max)[0]
        thr = jnp.min(jnp.where(jnp.arange(k_max) < jnp.minimum(k_eff, k_max),
                                prefix, jnp.inf))
        return jnp.where(jnp.abs(v) >= thr, v, 0.0)

    def encode(self, state, rng, x):
        u = self.momentum * state["u"].reshape(x.shape) + x
        v = state["v"].reshape(x.shape) + u
        v_enc = v
        if self.warmup_rounds:
            v_enc = self._anneal_mask(v, state["round"])
        payload, ist = self.inner.encode(state["inner"], rng, v_enc)
        v_hat = self.inner.decode(payload, v.shape[0])
        sent = v_hat != 0.0
        new_v = (v - v_hat).reshape(state["v"].shape)
        new_u = jnp.where(sent, 0.0, u).reshape(state["u"].shape)
        new_state = {"u": new_u, "v": new_v, "inner": ist}
        if self.warmup_rounds:
            new_state["round"] = state["round"] + 1
        return payload, new_state


def error_feedback(inner: CommTransform, decay: float = 1.0) -> CommTransform:
    return ErrorFeedback(inner, decay)


def momentum_correction(inner: CommTransform, momentum: float = 0.9,
                        warmup_rounds: int = 0,
                        final_fraction: float = 0.0) -> CommTransform:
    return MomentumCorrection(inner, momentum, warmup_rounds, final_fraction)
