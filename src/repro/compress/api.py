"""CommTransform protocol — the survey's §III.B.5 as *composable* wire stages.

The survey's central observation about practical systems is that they *layer*
reduction schemes: STC = top-k sparsification + ternary quantization, DGC =
sparsification + momentum correction, FetchSGD = sketching + top-k recovery.
The protocol here mirrors optax's ``GradientTransformation`` so those layers
compose instead of being one-off classes:

    init(leaf_shape)           -> state          (pipeline-owned, per leaf)
    encode(state, rng, x)      -> (payload, state')
    decode(payload, n)         -> x_hat: f32[n]

operating on flattened parameter/update leaves.  A transform may declare a
``carrier_key``: the payload entry holding the f32 values a *further* stage
may refine.  ``chain(topk(0.01), ternary())`` therefore *is* STC — top-k
emits ``{vals, idx}``, ternary re-encodes ``vals`` — and
``chain(topk(0.05), qsgd(8))`` is a new combined workload, all from one-line
spec strings (``"topk:0.01>>qsgd:8"``, see DESIGN.md §3).

Encoding happens *inside* the FL aggregation ``shard_map``
(``repro.core.aggregation``), so the payload arrays are exactly what crosses
the ICI/DCN links via ``all_gather`` — the compiled HLO's collective bytes
are the wire bytes.

Byte accounting (``CommLedger``, contract in DESIGN.md §1):
  * ``meta_bits(n)``    — bits of a stage's non-carrier side info (indices,
                          scales, signs) as dtype-packed on the link.
  * ``carrier_len(n)``  — length of the carrier a following stage refines.
  * ``wire_bits(n)``    — standalone total: ``meta + 32 * carrier_len`` (an
                          unrefined carrier travels as f32).  Chains sum the
                          per-stage ``meta_bits`` over the *shrinking* carrier
                          lengths, so compression ratios compose
                          multiplicatively.
  * ``entropy_bits(n)`` — same, under the source papers' entropy coders
                          (Golomb/Elias); reported alongside, never used for
                          shapes.

Biased transforms (top-k, STC, SBC, signSGD/HSQ) set ``biased = True``; the
FL layer wraps biased pipelines in ``error_feedback(...)`` (or
``momentum_correction(...)`` for DGC) — wrapping *transforms*, not special
cases in the trainer.  Their residual/momentum state lives in the pipeline
state threaded through ``FLState.comm_state``.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.compress.wire_format import WIRE_FORMATS

Payload = Dict[str, jax.Array]
PyTree = Any


class CommTransform:
    """One stage of the communication pipeline (optax-style)."""

    name: str = "base"
    biased: bool = False          # needs error feedback when used bare
    carrier_key: Optional[str] = None   # payload entry a next stage refines
    backend: str = "jax"          # "jax" | "kernel" (Pallas; DESIGN.md §6)
    kernel_capable: bool = False  # stage has a Pallas-backed encode path
    wire: str = "staged"          # "staged" | "packed" (DESIGN.md §10)

    # --- pipeline state ----------------------------------------------------
    def init(self, shape: Sequence[int]) -> PyTree:
        """Per-leaf state for a leaf of this shape. Must be zero-initialised
        arrays (the FL layer materialises a (C,)-leading client batch of
        them); stateless stages return ``()``."""
        return ()

    @property
    def stateful(self) -> bool:
        tmpl = jax.eval_shape(lambda: self.init((1,)))
        return len(jax.tree.leaves(tmpl)) > 0

    @property
    def is_identity(self) -> bool:
        return False

    # --- wire maps ---------------------------------------------------------
    def encode(self, state: PyTree, rng: jax.Array,
               x: jax.Array) -> Tuple[Payload, PyTree]:
        raise NotImplementedError

    def decode(self, payload: Payload, n: int) -> jax.Array:
        raise NotImplementedError

    # --- byte accounting ---------------------------------------------------
    def carrier_len(self, n: int) -> int:
        return 0

    def meta_bits(self, n: int) -> float:
        raise NotImplementedError

    def meta_entropy_bits(self, n: int) -> float:
        return self.meta_bits(n)

    # --- carrier-conditional entropy (DESIGN.md §1) -------------------------
    def carrier_hint(self, n: int):
        """Distributional hint about this stage's *carrier* values, consumed
        by the next stage's conditional entropy model.  None (default) means
        "assume the generic input distribution"; magnitude-selecting
        sparsifiers return ``{"kind": "top_tail", "fraction": k/n}`` so a
        following quantizer knows its input is the large-|x| tail (where
        Elias-coded levels are expensive)."""
        return None

    def meta_entropy_bits_given(self, n: int, hint=None) -> float:
        """``meta_entropy_bits`` conditioned on the preceding stage's carrier
        hint.  Stages without a conditional model fall back to the
        unconditional estimate."""
        return self.meta_entropy_bits(n)

    def wire_bits(self, n: int) -> float:
        return self.meta_bits(n) + 32.0 * self.carrier_len(n)

    def entropy_bits(self, n: int) -> float:
        return self.meta_entropy_bits(n) + 32.0 * self.carrier_len(n)

    # --- privacy accounting (DESIGN.md §11) --------------------------------
    def dp_rho_per_round(self) -> float:
        """zCDP rho this pipeline spends per client per round (0 unless a
        ``dpnoise`` stage is present).  Additive under composition, so the
        ledger accumulates it exactly like bytes."""
        return 0.0

    # --- stateless conveniences (the legacy ``Compressor`` surface) --------
    def compress(self, rng: jax.Array, x: jax.Array) -> Payload:
        payload, _ = self.encode(self.init(x.shape), rng, x)
        return payload

    def decompress(self, payload: Payload, n: int) -> jax.Array:
        return self.decode(payload, n)

    def roundtrip(self, rng, x):
        return self.decode(self.compress(rng, x), x.shape[0])


# legacy alias — pre-pipeline code and tests import ``Compressor``
Compressor = CommTransform


class Identity(CommTransform):
    """No compression — the FedAvg baseline (f32 on the wire). Acts as the
    unit of ``chain`` (it is filtered out of pipelines)."""
    name = "none"
    carrier_key = "x"

    def encode(self, state, rng, x):
        return {"x": x.astype(jnp.float32)}, state

    def decode(self, payload, n):
        return payload["x"]

    def carrier_len(self, n):
        return n

    def meta_bits(self, n):
        return 0.0

    @property
    def is_identity(self):
        return True


# ---------------------------------------------------------------------------
# Registry + spec-string grammar (DESIGN.md §3, §6, §10)
#
#   spec     := stage (">>" stage)*
#   stage    := name [":" arg ("," arg)*] ("@" suffix)*
#   name     := legacy registry name (exact match wins) | stage-factory name
#   arg      := number (int or float)
#   suffix   := "jax" | "kernel" (backend) | "fused" (packed wire format)
#
# Every pre-pipeline registry name ("qsgd8", "topk", "stc", "none", ...)
# resolves unchanged, with identical wire_bits.  A "@kernel" suffix routes
# that stage's encode through the Pallas kernels (repro.kernels.ops); the
# ``backend`` kwarg sets the default for every stage of the spec (stages
# without a kernel path keep the pure-JAX encode, but an *explicit*
# "@kernel" on such a stage fails loudly).
#
# Privacy stages (DESIGN.md §11) ride the same grammar with wrapping
# semantics: "qsgd:4>>secagg" masks the qsgd pipeline's integer code
# planes, "topk:0.05>>qsgd:4>>dpnoise:0.8" adds clipped Gaussian noise at
# the wire boundary.  They wrap everything to their left; a non-privacy
# stage after one is an error.
#
# "@fused" selects the PACKED wire format (DESIGN.md §10): the payload is
# the bit-packed int codes (2-bit ternary, nibble qsgd:<=4) instead of the
# storage-dtype staging buffers, and "stc@fused" is the fused dense-STC
# stage (codes over the full length, no indices).  ``wire_format="packed"``
# sets the default for every stage of the spec, same degrade rules as the
# backend kwarg; an explicit "@fused" on a stage with no packed format
# fails loudly.  Legacy registry names stay pinned to the staged format —
# their wire layout is frozen, only spec-grammar stages pack.
# ---------------------------------------------------------------------------

BACKENDS = ("jax", "kernel")

_REGISTRY: Dict[str, Callable[..., CommTransform]] = {}
_STAGES: Dict[str, Callable[..., CommTransform]] = {}


def register(name: str):
    """Register a legacy-name builder (kwargs-driven, e.g. ``qsgd8``)."""
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def register_stage(name: str):
    """Register a stage factory for the spec grammar (positional numeric
    args override the shared kwargs), e.g. ``qsgd`` for ``"qsgd:8"``."""
    def deco(fn):
        _STAGES[name] = fn
        return fn
    return deco


def _num(tok: str):
    tok = tok.strip()
    try:
        return int(tok)
    except ValueError:
        return float(tok)


def _make_stage(token: str, **kw) -> CommTransform:
    parts = [p.strip() for p in token.strip().split("@")]
    token, suffixes = parts[0], parts[1:]
    explicit_backend = explicit_wire = None
    for s in suffixes:
        if s == "fused":
            explicit_wire = "packed"
        elif s in BACKENDS:
            explicit_backend = s
        else:
            raise ValueError(
                f"unknown backend {s!r}; have {BACKENDS} (or 'fused' for "
                f"the packed wire format)")
    backend = explicit_backend or kw.get("backend", "jax")
    wire = explicit_wire or kw.get("wire_format", "staged")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")
    if wire not in WIRE_FORMATS:
        raise ValueError(f"unknown wire format {wire!r}; have {WIRE_FORMATS}")
    kw = dict(kw, backend=backend, wire=wire)
    if token in ("none", "identity", ""):
        stage = Identity()
    else:
        name, _, argstr = token.partition(":")
        name = name.strip()
        if not argstr and name in _REGISTRY:      # legacy exact names win
            stage = _REGISTRY[name](**kw)
        elif name not in _STAGES:
            known = sorted(set(_REGISTRY) | set(_STAGES))
            raise KeyError(f"unknown compressor stage {token!r}; have {known}")
        else:
            args = ([_num(a) for a in argstr.split(",") if a.strip()]
                    if argstr else [])
            stage = _STAGES[name](*args, **kw)
    if explicit_backend == "kernel" and not stage.kernel_capable:
        raise ValueError(
            f"stage {token!r} has no kernel backend (kernel-capable stages: "
            f"topk, qsgd, ternary, sketch — see DESIGN.md §6)")
    if explicit_wire == "packed" and stage.wire != "packed":
        raise ValueError(
            f"stage {token!r} has no packed wire format (packable stages: "
            f"ternary, qsgd with bits <= 4, stc — see DESIGN.md §10)")
    return stage


def make_compressor(spec: Optional[str], **kw) -> CommTransform:
    """Build a communication pipeline from a registry name or spec string.

    ``make_compressor("qsgd8")`` (legacy names, unchanged), or composed:
    ``make_compressor("topk:0.01>>qsgd:8")`` — top-k support with
    QSGD-quantised values.  ``kw`` (``fraction``, ``block``, ``rows``,
    ``cols``, ``backend``, ...) supplies defaults that per-stage positional
    args / ``@backend`` suffixes override: ``"topk:0.01@kernel>>qsgd:8"``
    runs the top-k masking pass through the Pallas kernel and QSGD pure;
    ``backend="kernel"`` selects the kernel path for every capable stage.
    """
    if spec in ("none", None, ""):
        return Identity()
    from repro.compress.pipeline import chain   # late import (cycle)
    from repro.compress import secure_agg       # late import (cycle)
    # privacy stages (secagg, dpnoise) are *wrapping* transforms, not
    # carrier-chained stages: each one wraps the whole pipeline to its left
    # ("qsgd:4>>secagg" = SecAgg over the qsgd pipeline), and nothing
    # non-private may follow — the wire boundary is the outermost layer.
    pipe, buf = None, []
    for tok in spec.split(">>"):
        head = tok.strip().split("@", 1)[0].split(":", 1)[0].strip()
        if head in secure_agg.PRIVACY_STAGES:
            inner = chain(*buf) if pipe is None else pipe
            pipe, buf = secure_agg.make_privacy_stage(tok, inner, **kw), []
        elif pipe is not None:
            raise ValueError(
                f"stage {tok.strip()!r} cannot follow a privacy stage — "
                f"secagg/dpnoise wrap everything before them; put carrier "
                f"stages first (e.g. 'topk:0.05>>qsgd:4>>secagg')")
        else:
            buf.append(_make_stage(tok, **kw))
    return pipe if pipe is not None else chain(*buf)


# ``make_pipeline`` is the forward-looking name; both resolve identically.
make_pipeline = make_compressor

register("none")(lambda **kw: Identity())
register_stage("none")(lambda **kw: Identity())
register_stage("identity")(lambda **kw: Identity())
