"""Compressor protocol — the survey's §III.B.5, as a composable operator.

A compressor is a *pure, shape-polymorphic, leaf-wise* pair of maps

    compress(rng, x: f32[n])            -> payload: dict[str, Array]
    decompress(payload, n)              -> f32[n]

operating on flattened parameter/update leaves.  Compression happens *inside*
the FL aggregation ``shard_map`` (``repro.core.aggregation``), so the payload
arrays are exactly what crosses the ICI/DCN links via ``all_gather`` — the
compiled HLO's collective bytes are the wire bytes.

Byte accounting (``CommLedger``):
  * ``wire_bits(n)``    — bits our dtype-packed payload occupies on the link.
  * ``entropy_bits(n)`` — bits the source paper's entropy coder (Golomb/Elias)
                          would achieve; reported alongside, never used for
                          shapes. See DESIGN.md §1 (hardware adaptation).

Biased compressors (top-k, STC, SBC, signSGD/HSQ) set ``biased = True`` and
are wrapped in error feedback by the FL layer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Payload = Dict[str, jax.Array]


class Compressor:
    name: str = "base"
    biased: bool = False

    def compress(self, rng: jax.Array, x: jax.Array) -> Payload:
        raise NotImplementedError

    def decompress(self, payload: Payload, n: int) -> jax.Array:
        raise NotImplementedError

    def wire_bits(self, n: int) -> float:
        raise NotImplementedError

    def entropy_bits(self, n: int) -> float:
        return self.wire_bits(n)

    # round-trip helper (used by error feedback and tests)
    def roundtrip(self, rng, x):
        return self.decompress(self.compress(rng, x), x.shape[0])


class Identity(Compressor):
    """No compression — the FedAvg baseline (f32 on the wire)."""
    name = "none"

    def compress(self, rng, x):
        return {"x": x.astype(jnp.float32)}

    def decompress(self, payload, n):
        return payload["x"]

    def wire_bits(self, n):
        return 32.0 * n


_REGISTRY: Dict[str, Callable[..., Compressor]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def make_compressor(name: str, **kw) -> Compressor:
    """Build a compressor by registry name, e.g. ``qsgd8``, ``topk``, ``stc``."""
    if name in ("none", None, ""):
        return Identity()
    if name not in _REGISTRY:
        raise KeyError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kw)


register("none")(lambda **kw: Identity())
