"""Quantization stages (survey §III.B.5 — Quantization).

  * ``qsgd8`` / ``qsgd4``  — FedPAQ's quantizer [45] = QSGD: stochastic uniform
    quantization with a per-block scale. Unbiased: E[Q(x)] = x.
  * ``lfl8``  — Lossy FL [70]: the same quantizer applied to the *downlink*
    (global-model broadcast); registered separately so ledger reporting can
    distinguish directions.
  * ``hsq``   — Hyper-Sphere-Quantization-style [71] 1-bit direction + per-block
    norm (the vector-codebook is degenerate to the sign codebook on TPU; see
    DESIGN.md §1). Biased -> error feedback.
  * ``uveq``  — UVeQFed-style [72] subtractive-dither uniform quantizer:
    dither u ~ U(-Δ/2, Δ/2) added before rounding and subtracted after —
    unbiased with bounded, input-independent distortion.

All are *terminal* pipeline stages (no carrier): they typically end a chain,
e.g. ``"topk:0.01>>qsgd:8"`` quantises the top-k values.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.compress.api import CommTransform, register, register_stage


def _norm_ppf(p: float) -> float:
    """Standard-normal quantile via bisection on math.erf (host-side, ledger
    terms only — no scipy in the image)."""
    lo, hi = 0.0, 12.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if 0.5 * (1.0 + math.erf(mid / math.sqrt(2.0))) < p:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def _tail_elias_bits_per_coord(levels: int, f: float, n: int,
                               block: int) -> float:
    """Expected Elias-gamma bits per coordinate when the quantizer input is
    the top-``f`` |x| tail of a Gaussian (a top-k carrier).

    The unconditional QSGD estimate (~bits+1/coord) assumes most levels are
    tiny; on a top-k carrier every |x| >= the (1-f) quantile while the
    per-block scale is the block *max*, so levels sit near full range and
    zigzag+Elias-gamma costs ~2*log2(2*level)+1.  Integrates
    E[2*log2(2*l+1)] over the truncated-normal tail (the +1 stop bit and
    the floor in the code length cancel in expectation)."""
    t = _norm_ppf(1.0 - f / 2.0)                   # P(|x| > t) = f
    bl = max(1, min(block, n))
    # per-block scale ~ the max of bl tail draws = the |x| quantile at
    # tail probability f/bl
    scale = _norm_ppf(1.0 - f / (2.0 * bl))
    import numpy as np
    trapezoid = getattr(np, "trapezoid", None) or np.trapz   # numpy<2 compat
    xs = np.linspace(t, scale, 513)
    dens = np.exp(-xs * xs / 2.0)
    lev = np.minimum(levels * xs / scale, float(levels))
    bits = 2.0 * np.log2(2.0 * lev + 1.0)
    z = trapezoid(dens, xs)
    return float(trapezoid(bits * dens, xs) / z) if z > 0 else 2.0 * math.log2(
        2.0 * levels + 1.0)


def _blocked(x, block):
    n = x.shape[0]
    # adapt to short inputs (e.g. a chain carrier of k << block values):
    # one block of length n instead of zero-padding to a full block, so the
    # payload that crosses the wire matches the ledger's 8n + 32*nb bits
    block = max(1, min(block, n))
    nb = -(-n // block)
    pad = nb * block - n
    xb = jnp.pad(x, (0, pad)).reshape(nb, block)
    return xb, nb, pad


class QSGD(CommTransform):
    """Stochastic uniform quantization, per-block max-abs scale, int8 wire.

    ``backend="kernel"`` routes the fused (scale -> normalise -> stochastic
    round -> int8) pass through the Pallas kernel (``repro.kernels.qsgd``).
    The stochastic-rounding uniforms are sampled in the *pure-JAX blocked
    layout* on both backends, so the kernel path is bit-exact against the
    reference (tests/test_kernel_parity.py).

    ``wire="packed"`` (the ``@fused`` suffix; ``bits <= 4`` only) nibble-
    packs the flat code vector: two codes per byte, ``8*ceil(n/2)`` wire
    bits instead of ``8n``, ledger == payload bytes exactly (DESIGN.md
    §10).  The kernel path fuses the pack into the quantize pass
    (``repro.kernels.bitpack``) so the int8 codes never round-trip HBM."""
    kernel_capable = True

    def __init__(self, bits=8, block=2048, backend="jax", wire="staged"):
        assert 2 <= bits <= 8
        if wire == "packed" and bits > 4:
            raise ValueError(
                f"qsgd:{bits} has no packed wire format — the nibble holds "
                f"levels in [-8, 7], use bits <= 4 for '@fused' "
                f"(DESIGN.md §10)")
        self.bits = bits
        self.block = block
        self.levels = 2 ** (bits - 1) - 1        # signed levels
        self.backend = backend
        self.wire = wire
        self.name = (f"qsgd{bits}"
                     + ("@kernel" if backend == "kernel" else "")
                     + ("@fused" if wire == "packed" else ""))

    def encode(self, state, rng, x):
        n = x.shape[0]
        xb, nb, _ = _blocked(x.astype(jnp.float32), self.block)
        u = jax.random.uniform(rng, xb.shape, jnp.float32)
        if self.backend == "kernel":
            from repro.kernels import ops
            # same per-element uniforms as the pure path (pads sit at the
            # end of the flat vector in both blockings), and the same
            # short-input-adapted block (xb.shape[1]) — so the kernel
            # payload SHAPE matches the pure path exactly and a short
            # chain carrier (k < block) never ships full-width rows
            if self.wire == "packed":
                q4, scale = ops.qsgd_quantize_packed(x, u.reshape(-1)[:n],
                                                     self.bits, xb.shape[1])
                return {"q4": q4, "scale": scale}, state
            q, scale = ops.qsgd_quantize(x, u.reshape(-1)[:n],
                                         self.bits, xb.shape[1])
            return {"q": q, "scale": scale}, state
        scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
        y = xb / jnp.maximum(scale, 1e-30) * self.levels
        q = jnp.floor(y + u).astype(jnp.int8)
        if self.wire == "packed":
            from repro.compress.wire_format import pack4
            return {"q4": pack4(q.reshape(-1)[:n]),
                    "scale": scale[:, 0]}, state
        return {"q": q, "scale": scale[:, 0]}, state

    def decode(self, payload, n):
        if self.wire == "packed":
            from repro.compress.wire_format import unpack4
            block = max(1, min(self.block, n))    # mirror _blocked's adapt
            nb = -(-n // block)
            q = jnp.pad(unpack4(payload["q4"], n),
                        (0, nb * block - n)).astype(jnp.float32)
            q = q.reshape(nb, block)
        else:
            q = payload["q"].astype(jnp.float32)
        scale = payload["scale"][:, None]
        x = q / self.levels * scale
        return x.reshape(-1)[:n]

    def meta_bits(self, n):
        nb = -(-n // self.block)
        if self.wire == "packed":
            return 8.0 * (-(-n // 2)) + 32.0 * nb   # nibbles + f32 scales
        return 8.0 * n + 32.0 * nb               # int8 storage + f32 scales

    def meta_entropy_bits(self, n):
        nb = -(-n // self.block)
        # Elias-coded QSGD costs ~bits+1 per coordinate; at 8 bits the int8
        # dtype packing is already at least as tight, so take the min.
        est = min(float(self.bits + 1), 8.0) * n + 32.0 * nb
        # packed wire: the nibble packing may already beat the coder model
        return min(est, self.meta_bits(n)) if self.wire == "packed" else est

    def meta_entropy_bits_given(self, n, hint=None):
        if not hint or hint.get("kind") != "top_tail":
            return self.meta_entropy_bits(n)
        # carrier-conditional model: on a top-k carrier the levels are large
        # and Elias-gamma can exceed the int8 packing — report the modelled
        # coder cost instead of the independent-stage optimistic min()
        nb = -(-n // self.block)
        bpc = _tail_elias_bits_per_coord(self.levels, float(hint["fraction"]),
                                         n, self.block)
        est = bpc * n + 32.0 * nb
        return min(est, self.meta_bits(n)) if self.wire == "packed" else est


class UVeQ(CommTransform):
    """Subtractive-dither uniform quantization (UVeQFed-style, unbiased)."""

    def __init__(self, bits=4, block=2048):
        self.bits = bits
        self.block = block
        self.name = f"uveq{bits}"

    def encode(self, state, rng, x):
        xb, nb, _ = _blocked(x.astype(jnp.float32), self.block)
        scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
        delta = jnp.maximum(scale, 1e-30) / (2 ** (self.bits - 1) - 1)
        u = jax.random.uniform(rng, xb.shape, jnp.float32, -0.5, 0.5) * delta
        q = jnp.round((xb + u) / delta).astype(jnp.int8)
        return {"q": q, "scale": scale[:, 0], "useed": rng}, state

    def decode(self, payload, n):
        scale = payload["scale"][:, None]
        delta = jnp.maximum(scale, 1e-30) / (2 ** (self.bits - 1) - 1)
        xb = payload["q"].astype(jnp.float32) * delta
        # subtractive dither: receiver regenerates u from the shared seed
        u = jax.random.uniform(payload["useed"], xb.shape, jnp.float32, -0.5, 0.5) * delta
        return (xb - u).reshape(-1)[:n]

    def meta_bits(self, n):
        nb = -(-n // self.block)
        return 8.0 * n + 32.0 * nb + 32.0

    def meta_entropy_bits(self, n):
        nb = -(-n // self.block)
        return float(self.bits) * n + 32.0 * nb + 32.0


class HSQ(CommTransform):
    """1-bit sign + per-block l2-scaled magnitude (HSQ's codebook degenerated
    to the sign hypersphere — the TPU-idiomatic variant)."""
    biased = True

    def __init__(self, block=2048):
        self.block = block
        self.name = "hsq"

    def encode(self, state, rng, x):
        xb, nb, _ = _blocked(x.astype(jnp.float32), self.block)
        mu = jnp.mean(jnp.abs(xb), axis=1)
        return {"sign": jnp.sign(xb).astype(jnp.int8), "mu": mu}, state

    def decode(self, payload, n):
        xb = payload["sign"].astype(jnp.float32) * payload["mu"][:, None]
        return xb.reshape(-1)[:n]

    def meta_bits(self, n):
        nb = -(-n // self.block)
        return 8.0 * n + 32.0 * nb               # int8-stored signs

    def meta_entropy_bits(self, n):
        nb = -(-n // self.block)
        return 1.0 * n + 32.0 * nb               # 1 bit/sign after packing


register("qsgd8")(lambda block=2048, backend="jax", **kw:
                  QSGD(8, block, backend))
register("qsgd4")(lambda block=2048, backend="jax", **kw:
                  QSGD(4, block, backend))
register("lfl8")(lambda block=2048, backend="jax", **kw:
                 QSGD(8, block, backend))
register("uveq")(lambda block=2048, **kw: UVeQ(4, block))
register("hsq")(lambda block=2048, **kw: HSQ(block))

# a GLOBAL wire_format="packed" degrades gracefully on qsgd:>4 (stays
# staged, like backend="kernel" on a kernel-less stage); the explicit
# "@fused" suffix on it still fails loudly in _make_stage
register_stage("qsgd")(lambda bits=8, blk=None, block=2048, backend="jax",
                       wire="staged", **kw:
                       QSGD(int(bits), int(blk or block), backend,
                            wire if int(bits) <= 4 else "staged"))
register_stage("uveq")(lambda bits=4, blk=None, block=2048, **kw:
                       UVeQ(int(bits), int(blk or block)))
register_stage("hsq")(lambda blk=None, block=2048, **kw: HSQ(int(blk or block)))
