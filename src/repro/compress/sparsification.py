"""Sparsification stages (survey §III.B.5 — Sparsification).

  * ``topk``     — magnitude top-k with (values, indices) wire format; the GGS
    [67] setting. Carrier = the k surviving values, so further stages refine
    them: ``chain(topk, ternary)`` *is* STC, ``chain(topk, qsgd)`` is the
    quantised-sparse combined scheme. Biased -> error feedback.
  * ``ternary``  — STC's quantization half [39]: values -> sign(x)·mean(|x|).
    Wire = signs + one scalar; the paper's Golomb coding is reported via
    ``entropy_bits``.
  * ``stc``      — legacy name for ``chain(topk, ternary)`` (bit-for-bit the
    old monolithic STC compressor).
  * ``sbc``      — Sparse Binary Compression [69]: keep only the dominant-sign
    half of the top-k support, average its magnitudes (1 fewer bit than STC).
  * ``randmask`` — CPFed [68]: data-independent random mask (unbiased after
    1/p rescale) + optional Gaussian noise on the surviving values (DP).
    Carrier = surviving values (only they travel; the mask rides a seed).

All operate on flattened f32 leaves; k is a static fraction of n (fixed shapes
under jit — matching the source papers' fixed-sparsity setting).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.compress.api import CommTransform, register, register_stage


def _k(n, fraction):
    return max(1, int(round(n * fraction)))


class TopK(CommTransform):
    """``backend="kernel"``: the dense masking pass runs through the fused
    ``threshold_sparsify`` Pallas kernel; index *extraction* stays in XLA
    (``lax.top_k`` — TPUs have no in-kernel compaction, DESIGN.md §6).
    ``top_k`` breaks magnitude ties by ascending index on both the raw and
    the masked vector, so the kernel path is bit-exact against pure JAX."""
    biased = True
    carrier_key = "vals"
    kernel_capable = True

    def __init__(self, fraction=0.01, block=2048, backend="jax"):
        self.fraction = fraction
        self.block = block
        self.backend = backend
        self.name = f"topk{fraction:g}" + \
            ("@kernel" if backend == "kernel" else "")

    def encode(self, state, rng, x):
        n = x.shape[0]
        k = _k(n, self.fraction)
        vals, idx = jax.lax.top_k(jnp.abs(x), k)
        if self.backend == "kernel":
            from repro.kernels import ops
            # ONE top_k for threshold + indices; the fused kernel pass
            # produces the dense masked vector the payload values are
            # gathered from (kept[idx] == x[idx] bit-exactly, ties
            # included). The pass also emits the EF residual — wiring it
            # into the ErrorFeedback wrapper is the roadmap's TPU HBM win.
            kept, _ = ops.threshold_sparsify(x, vals[-1], self.block)
            return {"vals": kept[idx], "idx": idx.astype(jnp.int32)}, state
        return {"vals": x[idx], "idx": idx.astype(jnp.int32)}, state

    def decode(self, payload, n):
        out = jnp.zeros((n,), jnp.float32)
        return out.at[payload["idx"]].set(payload["vals"].astype(jnp.float32))

    def carrier_len(self, n):
        return _k(n, self.fraction)

    def meta_bits(self, n):
        return _k(n, self.fraction) * 32.0       # int32 indices

    def meta_entropy_bits(self, n):
        k = _k(n, self.fraction)
        idx_bits = math.log2(max(n / k, 2.0)) + 2      # Golomb-coded gaps
        return k * idx_bits

    def carrier_hint(self, n):
        # the carrier is the top-|x| tail: a following quantizer's levels
        # concentrate near full scale, where Elias-gamma is expensive
        return {"kind": "top_tail", "fraction": _k(n, self.fraction) / n}


class Ternary(CommTransform):
    """Ternarisation to ±mean(|x|) — STC's quantizer, as a chainable stage.

    ``backend="kernel"``: signs + the |x| partial sums come from one fused
    ``ternarize_blocked`` pass. Signs are bit-exact; mu differs from the
    pure path by reduction *order* only (per-row partials then a row sum vs
    one flat sum) — the documented bounded-ULP parity class.

    ``wire="packed"`` (the ``@fused`` suffix): the payload is the 2-bit
    packed sign vector — ``8*ceil(n/4) + 32`` wire bits instead of
    ``8n + 32``, ledger == payload bytes exactly (DESIGN.md §10).  The
    kernel path packs inside the ternarise pass (``kernels.bitpack``)."""
    biased = True
    kernel_capable = True

    def __init__(self, block=2048, backend="jax", wire="staged"):
        self.block = block
        self.backend = backend
        self.wire = wire
        self.name = ("ternary" + ("@kernel" if backend == "kernel" else "")
                     + ("@fused" if wire == "packed" else ""))

    def encode(self, state, rng, x):
        n = x.shape[0]
        if self.backend == "kernel":
            from repro.kernels import ops
            if self.wire == "packed":
                packed, abs_sum = ops.ternarize_signs_packed(x, self.block)
                return {"mu": abs_sum / n, "sign2": packed}, state
            sign, abs_sum = ops.ternarize_signs(x, self.block)
            return {"mu": abs_sum / n, "sign": sign}, state
        mu = jnp.abs(x).mean()
        sign = jnp.sign(x).astype(jnp.int8)
        if self.wire == "packed":
            from repro.compress.wire_format import pack2
            return {"mu": mu, "sign2": pack2(sign)}, state
        return {"mu": mu, "sign": sign}, state

    def decode(self, payload, n):
        if self.wire == "packed":
            from repro.compress.wire_format import unpack2
            sign = unpack2(payload["sign2"], n)
        else:
            sign = payload["sign"]
        return sign.astype(jnp.float32) * payload["mu"]

    def meta_bits(self, n):
        if self.wire == "packed":
            return 8.0 * (-(-n // 4)) + 32.0     # 2-bit packed signs + mu
        return 8.0 * n + 32.0                    # int8 signs + f32 mu

    def meta_entropy_bits(self, n):
        return 1.0 * n + 32.0                    # 1 bit/sign after packing


class SBC(CommTransform):
    """Sattler et al. [69]: binary — keep only the dominant sign's support."""
    biased = True

    def __init__(self, fraction=0.01):
        self.fraction = fraction
        self.name = f"sbc{fraction:g}"

    def encode(self, state, rng, x):
        n = x.shape[0]
        k = _k(n, self.fraction)
        mag, idx = jax.lax.top_k(jnp.abs(x), k)
        v = x[idx]
        pos_sum = jnp.sum(jnp.where(v > 0, v, 0.0))
        neg_sum = -jnp.sum(jnp.where(v < 0, v, 0.0))
        s = jnp.where(pos_sum >= neg_sum, 1.0, -1.0)
        keep = (jnp.sign(v) == s)
        mu = jnp.sum(jnp.abs(v) * keep) / jnp.maximum(keep.sum(), 1)
        # drop the minority-sign entries (their index slot points to 0 weight)
        idx = jnp.where(keep, idx, n)              # n => scatter-dropped
        return {"mu": mu * s, "idx": idx.astype(jnp.int32)}, state

    def decode(self, payload, n):
        out = jnp.zeros((n + 1,), jnp.float32)
        out = out.at[payload["idx"]].set(payload["mu"])
        return out[:n]

    def meta_bits(self, n):
        return _k(n, self.fraction) * 32.0 + 32.0

    def meta_entropy_bits(self, n):
        k = _k(n, self.fraction)
        idx_bits = math.log2(max(n / k, 2.0)) + 2
        return k * idx_bits + 32.0


class RandMask(CommTransform):
    """CPFed [68]: random-mask sparsifier (unbiased, 1/p rescale) with optional
    Gaussian noise on survivors (differential privacy)."""
    biased = False
    carrier_key = "vals"

    def __init__(self, fraction=0.05, dp_sigma=0.0):
        self.fraction = fraction
        self.dp_sigma = dp_sigma
        self.name = f"randmask{fraction:g}"

    def _idx(self, seed_key, n):
        k = _k(n, self.fraction)
        # data-independent mask: pseudo-random permutation from a shared seed
        scores = jax.random.uniform(seed_key, (n,))
        _, idx = jax.lax.top_k(scores, k)
        return idx

    def encode(self, state, rng, x):
        n = x.shape[0]
        seed, noise = jax.random.split(rng)
        idx = self._idx(seed, n)
        vals = x[idx] / self.fraction
        if self.dp_sigma:
            vals = vals + self.dp_sigma * jax.random.normal(noise, vals.shape)
        return {"vals": vals, "seed": seed}, state

    def decode(self, payload, n):
        idx = self._idx(payload["seed"], n)
        out = jnp.zeros((n,), jnp.float32)
        return out.at[idx].set(payload["vals"].astype(jnp.float32))

    def carrier_len(self, n):
        return _k(n, self.fraction)

    def meta_bits(self, n):
        # indices are regenerated from the 64-bit seed — only values travel
        return 64.0


class FusedSTC(CommTransform):
    """``stc@fused`` — the dense packed STC wire format (DESIGN.md §10).

    The staged ``stc`` chain (top-k >> ternary) ships 32-bit indices plus
    8-bit signs per survivor: ``40k + 32`` bits.  This stage ships 2-bit
    ternary codes over the FULL length instead — no indices at all —
    ``8*ceil(n/4) + 32 ≈ 2n`` bits, a strict win whenever the kept
    fraction exceeds ~0.05 (and position-free, so it packs into a plain
    dense collective).  The kernel path is ``ops.stc_ternarize`` end to
    end as ONE pass: threshold -> sign -> 2-bit pack + mu partials, the
    codes never round-tripping HBM (``kernels.bitpack``).

    Support semantics: every |x| >= the k-th magnitude is kept, so exact
    magnitude ties may keep MORE than k coordinates (the staged chain's
    ``top_k`` breaks ties by index) — measure zero on float inputs, and
    the reason fused-vs-staged parity is the bounded-ULP class while the
    kernel-vs-jax parity of this stage is sign-exact."""
    biased = True
    kernel_capable = True
    wire = "packed"

    def __init__(self, fraction=0.01, block=2048, backend="jax"):
        self.fraction = fraction
        self.block = block
        self.backend = backend
        self.name = (f"stc{fraction:g}"
                     + ("@kernel" if backend == "kernel" else "") + "@fused")

    def encode(self, state, rng, x):
        n = x.shape[0]
        if self.backend == "kernel":
            from repro.kernels import ops
            packed, mu = ops.stc_ternarize_packed(x, self.fraction,
                                                  self.block)
            return {"mu": mu, "code2": packed}, state
        from repro.compress.wire_format import pack2
        k = _k(n, self.fraction)
        mag = jnp.abs(x)
        # min over the prefix, not a scalar slice: a slice fused into
        # top_k defeats XLA's TopkRewriter — kernels.ops._stc_threshold
        thresh = jnp.min(jax.lax.top_k(mag, k)[0])
        keep = mag >= thresh
        code = (jnp.sign(x) * keep).astype(jnp.int8)
        mu = jnp.sum(jnp.where(keep, mag, 0.0)) / jnp.maximum(keep.sum(), 1)
        return {"mu": mu, "code2": pack2(code)}, state

    def decode(self, payload, n):
        from repro.compress.wire_format import unpack2
        return unpack2(payload["code2"], n).astype(jnp.float32) * \
            payload["mu"]

    def meta_bits(self, n):
        return 8.0 * (-(-n // 4)) + 32.0         # 2-bit packed codes + mu

    def meta_entropy_bits(self, n):
        # same information as the staged STC chain: k gap-coded positions
        # + 1 sign bit each (run-length over the 2-bit stream); never more
        # than the packed wire itself
        k = _k(n, self.fraction)
        idx_bits = math.log2(max(n / k, 2.0)) + 2
        return min(k * (idx_bits + 1.0) + 32.0, self.meta_bits(n))


def _stc(fraction=0.01, block=2048, backend="jax", wire="staged"):
    if wire == "packed":
        return FusedSTC(fraction, block, backend)
    from repro.compress.pipeline import chain
    return chain(TopK(fraction, block, backend), Ternary(block, backend))


register("topk")(lambda fraction=0.01, block=2048, backend="jax", **kw:
                 TopK(fraction, block, backend))
register("stc")(lambda fraction=0.01, block=2048, backend="jax",
                wire="staged", **kw: _stc(fraction, block, backend, wire))
register("sbc")(lambda fraction=0.01, **kw: SBC(fraction))
register("randmask")(lambda fraction=0.05, dp_sigma=0.0, **kw:
                     RandMask(fraction, dp_sigma))

register_stage("topk")(lambda frac=None, fraction=0.01, block=2048,
                       backend="jax", **kw:
                       TopK(float(frac if frac is not None else fraction),
                            int(block), backend))
register_stage("ternary")(lambda block=2048, backend="jax", wire="staged",
                          **kw: Ternary(int(block), backend, wire))
register_stage("stc")(lambda frac=None, fraction=0.01, block=2048,
                      backend="jax", wire="staged", **kw:
                      _stc(float(frac if frac is not None else fraction),
                           int(block), backend, wire))
register_stage("sbc")(lambda frac=None, fraction=0.01, **kw:
                      SBC(float(frac if frac is not None else fraction)))
register_stage("randmask")(lambda frac=None, fraction=0.05, dp_sigma=0.0, **kw:
                           RandMask(float(frac if frac is not None
                                          else fraction), dp_sigma))
