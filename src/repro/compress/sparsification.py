"""Sparsification stages (survey §III.B.5 — Sparsification).

  * ``topk``     — magnitude top-k with (values, indices) wire format; the GGS
    [67] setting. Carrier = the k surviving values, so further stages refine
    them: ``chain(topk, ternary)`` *is* STC, ``chain(topk, qsgd)`` is the
    quantised-sparse combined scheme. Biased -> error feedback.
  * ``ternary``  — STC's quantization half [39]: values -> sign(x)·mean(|x|).
    Wire = signs + one scalar; the paper's Golomb coding is reported via
    ``entropy_bits``.
  * ``stc``      — legacy name for ``chain(topk, ternary)`` (bit-for-bit the
    old monolithic STC compressor).
  * ``sbc``      — Sparse Binary Compression [69]: keep only the dominant-sign
    half of the top-k support, average its magnitudes (1 fewer bit than STC).
  * ``randmask`` — CPFed [68]: data-independent random mask (unbiased after
    1/p rescale) + optional Gaussian noise on the surviving values (DP).
    Carrier = surviving values (only they travel; the mask rides a seed).

All operate on flattened f32 leaves; k is a static fraction of n (fixed shapes
under jit — matching the source papers' fixed-sparsity setting).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.compress.api import CommTransform, register, register_stage


def _k(n, fraction):
    return max(1, int(round(n * fraction)))


class TopK(CommTransform):
    """``backend="kernel"``: the dense masking pass runs through the fused
    ``threshold_sparsify`` Pallas kernel; index *extraction* stays in XLA
    (``lax.top_k`` — TPUs have no in-kernel compaction, DESIGN.md §6).
    ``top_k`` breaks magnitude ties by ascending index on both the raw and
    the masked vector, so the kernel path is bit-exact against pure JAX."""
    biased = True
    carrier_key = "vals"
    kernel_capable = True

    def __init__(self, fraction=0.01, block=2048, backend="jax"):
        self.fraction = fraction
        self.block = block
        self.backend = backend
        self.name = f"topk{fraction:g}" + \
            ("@kernel" if backend == "kernel" else "")

    def encode(self, state, rng, x):
        n = x.shape[0]
        k = _k(n, self.fraction)
        vals, idx = jax.lax.top_k(jnp.abs(x), k)
        if self.backend == "kernel":
            from repro.kernels import ops
            # ONE top_k for threshold + indices; the fused kernel pass
            # produces the dense masked vector the payload values are
            # gathered from (kept[idx] == x[idx] bit-exactly, ties
            # included). The pass also emits the EF residual — wiring it
            # into the ErrorFeedback wrapper is the roadmap's TPU HBM win.
            kept, _ = ops.threshold_sparsify(x, vals[-1], self.block)
            return {"vals": kept[idx], "idx": idx.astype(jnp.int32)}, state
        return {"vals": x[idx], "idx": idx.astype(jnp.int32)}, state

    def decode(self, payload, n):
        out = jnp.zeros((n,), jnp.float32)
        return out.at[payload["idx"]].set(payload["vals"].astype(jnp.float32))

    def carrier_len(self, n):
        return _k(n, self.fraction)

    def meta_bits(self, n):
        return _k(n, self.fraction) * 32.0       # int32 indices

    def meta_entropy_bits(self, n):
        k = _k(n, self.fraction)
        idx_bits = math.log2(max(n / k, 2.0)) + 2      # Golomb-coded gaps
        return k * idx_bits

    def carrier_hint(self, n):
        # the carrier is the top-|x| tail: a following quantizer's levels
        # concentrate near full scale, where Elias-gamma is expensive
        return {"kind": "top_tail", "fraction": _k(n, self.fraction) / n}


class Ternary(CommTransform):
    """Ternarisation to ±mean(|x|) — STC's quantizer, as a chainable stage.

    ``backend="kernel"``: signs + the |x| partial sums come from one fused
    ``ternarize_blocked`` pass. Signs are bit-exact; mu differs from the
    pure path by reduction *order* only (per-row partials then a row sum vs
    one flat sum) — the documented bounded-ULP parity class."""
    biased = True
    kernel_capable = True

    def __init__(self, block=2048, backend="jax"):
        self.block = block
        self.backend = backend
        self.name = "ternary" + ("@kernel" if backend == "kernel" else "")

    def encode(self, state, rng, x):
        if self.backend == "kernel":
            from repro.kernels import ops
            sign, abs_sum = ops.ternarize_signs(x, self.block)
            return {"mu": abs_sum / x.shape[0], "sign": sign}, state
        mu = jnp.abs(x).mean()
        return {"mu": mu, "sign": jnp.sign(x).astype(jnp.int8)}, state

    def decode(self, payload, n):
        return payload["sign"].astype(jnp.float32) * payload["mu"]

    def meta_bits(self, n):
        return 8.0 * n + 32.0                    # int8 signs + f32 mu

    def meta_entropy_bits(self, n):
        return 1.0 * n + 32.0                    # 1 bit/sign after packing


class SBC(CommTransform):
    """Sattler et al. [69]: binary — keep only the dominant sign's support."""
    biased = True

    def __init__(self, fraction=0.01):
        self.fraction = fraction
        self.name = f"sbc{fraction:g}"

    def encode(self, state, rng, x):
        n = x.shape[0]
        k = _k(n, self.fraction)
        mag, idx = jax.lax.top_k(jnp.abs(x), k)
        v = x[idx]
        pos_sum = jnp.sum(jnp.where(v > 0, v, 0.0))
        neg_sum = -jnp.sum(jnp.where(v < 0, v, 0.0))
        s = jnp.where(pos_sum >= neg_sum, 1.0, -1.0)
        keep = (jnp.sign(v) == s)
        mu = jnp.sum(jnp.abs(v) * keep) / jnp.maximum(keep.sum(), 1)
        # drop the minority-sign entries (their index slot points to 0 weight)
        idx = jnp.where(keep, idx, n)              # n => scatter-dropped
        return {"mu": mu * s, "idx": idx.astype(jnp.int32)}, state

    def decode(self, payload, n):
        out = jnp.zeros((n + 1,), jnp.float32)
        out = out.at[payload["idx"]].set(payload["mu"])
        return out[:n]

    def meta_bits(self, n):
        return _k(n, self.fraction) * 32.0 + 32.0

    def meta_entropy_bits(self, n):
        k = _k(n, self.fraction)
        idx_bits = math.log2(max(n / k, 2.0)) + 2
        return k * idx_bits + 32.0


class RandMask(CommTransform):
    """CPFed [68]: random-mask sparsifier (unbiased, 1/p rescale) with optional
    Gaussian noise on survivors (differential privacy)."""
    biased = False
    carrier_key = "vals"

    def __init__(self, fraction=0.05, dp_sigma=0.0):
        self.fraction = fraction
        self.dp_sigma = dp_sigma
        self.name = f"randmask{fraction:g}"

    def _idx(self, seed_key, n):
        k = _k(n, self.fraction)
        # data-independent mask: pseudo-random permutation from a shared seed
        scores = jax.random.uniform(seed_key, (n,))
        _, idx = jax.lax.top_k(scores, k)
        return idx

    def encode(self, state, rng, x):
        n = x.shape[0]
        seed, noise = jax.random.split(rng)
        idx = self._idx(seed, n)
        vals = x[idx] / self.fraction
        if self.dp_sigma:
            vals = vals + self.dp_sigma * jax.random.normal(noise, vals.shape)
        return {"vals": vals, "seed": seed}, state

    def decode(self, payload, n):
        idx = self._idx(payload["seed"], n)
        out = jnp.zeros((n,), jnp.float32)
        return out.at[idx].set(payload["vals"].astype(jnp.float32))

    def carrier_len(self, n):
        return _k(n, self.fraction)

    def meta_bits(self, n):
        # indices are regenerated from the 64-bit seed — only values travel
        return 64.0


def _stc(fraction=0.01, block=2048, backend="jax"):
    from repro.compress.pipeline import chain
    return chain(TopK(fraction, block, backend), Ternary(block, backend))


register("topk")(lambda fraction=0.01, block=2048, backend="jax", **kw:
                 TopK(fraction, block, backend))
register("stc")(lambda fraction=0.01, block=2048, backend="jax", **kw:
                _stc(fraction, block, backend))
register("sbc")(lambda fraction=0.01, **kw: SBC(fraction))
register("randmask")(lambda fraction=0.05, dp_sigma=0.0, **kw:
                     RandMask(fraction, dp_sigma))

register_stage("topk")(lambda frac=None, fraction=0.01, block=2048,
                       backend="jax", **kw:
                       TopK(float(frac if frac is not None else fraction),
                            int(block), backend))
register_stage("ternary")(lambda block=2048, backend="jax", **kw:
                          Ternary(int(block), backend))
register_stage("stc")(lambda frac=None, fraction=0.01, block=2048,
                      backend="jax", **kw:
                      _stc(float(frac if frac is not None else fraction),
                           int(block), backend))
register_stage("sbc")(lambda frac=None, fraction=0.01, **kw:
                      SBC(float(frac if frac is not None else fraction)))
register_stage("randmask")(lambda frac=None, fraction=0.05, dp_sigma=0.0, **kw:
                           RandMask(float(frac if frac is not None
                                          else fraction), dp_sigma))
