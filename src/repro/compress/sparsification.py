"""Sparsification compressors (survey §III.B.5 — Sparsification).

  * ``topk``     — magnitude top-k with (values, indices) wire format; the GGS
    [67] setting. Biased -> error feedback at the FL layer.
  * ``stc``      — Sparse Ternary Compression [39]: top-k support, values
    ternarised to ±mean(|top-k|). Wire = indices + signs + one scalar.
    The paper's Golomb coding is reported via ``entropy_bits``.
  * ``sbc``      — Sparse Binary Compression [69]: keep only the dominant-sign
    half of the top-k support, average its magnitudes (1 fewer bit than STC).
  * ``randmask`` — CPFed [68]: data-independent random mask (unbiased after
    1/p rescale) + optional Gaussian noise on the surviving values (DP).

All operate on flattened f32 leaves; k is a static fraction of n (fixed shapes
under jit — matching the source papers' fixed-sparsity setting).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.compress.api import Compressor, register


def _k(n, fraction):
    return max(1, int(round(n * fraction)))


class TopK(Compressor):
    biased = True

    def __init__(self, fraction=0.01):
        self.fraction = fraction
        self.name = f"topk{fraction:g}"

    def compress(self, rng, x):
        n = x.shape[0]
        k = _k(n, self.fraction)
        vals, idx = jax.lax.top_k(jnp.abs(x), k)
        return {"vals": x[idx], "idx": idx.astype(jnp.int32)}

    def decompress(self, payload, n):
        out = jnp.zeros((n,), jnp.float32)
        return out.at[payload["idx"]].set(payload["vals"].astype(jnp.float32))

    def wire_bits(self, n):
        return _k(n, self.fraction) * (32.0 + 32.0)

    def entropy_bits(self, n):
        k = _k(n, self.fraction)
        idx_bits = math.log2(max(n / k, 2.0)) + 2      # Golomb-coded gaps
        return k * (32.0 + idx_bits)


class STC(Compressor):
    """Sattler et al. [39]: top-k + ternarisation (±mu)."""
    biased = True

    def __init__(self, fraction=0.01):
        self.fraction = fraction
        self.name = f"stc{fraction:g}"

    def compress(self, rng, x):
        n = x.shape[0]
        k = _k(n, self.fraction)
        mag, idx = jax.lax.top_k(jnp.abs(x), k)
        mu = mag.mean()
        return {"mu": mu, "idx": idx.astype(jnp.int32),
                "sign": jnp.sign(x[idx]).astype(jnp.int8)}

    def decompress(self, payload, n):
        out = jnp.zeros((n,), jnp.float32)
        vals = payload["sign"].astype(jnp.float32) * payload["mu"]
        return out.at[payload["idx"]].set(vals)

    def wire_bits(self, n):
        return _k(n, self.fraction) * (32.0 + 8.0) + 32.0

    def entropy_bits(self, n):
        k = _k(n, self.fraction)
        idx_bits = math.log2(max(n / k, 2.0)) + 2
        return k * (idx_bits + 1.0) + 32.0


class SBC(Compressor):
    """Sattler et al. [69]: binary — keep only the dominant sign's support."""
    biased = True

    def __init__(self, fraction=0.01):
        self.fraction = fraction
        self.name = f"sbc{fraction:g}"

    def compress(self, rng, x):
        n = x.shape[0]
        k = _k(n, self.fraction)
        mag, idx = jax.lax.top_k(jnp.abs(x), k)
        v = x[idx]
        pos_sum = jnp.sum(jnp.where(v > 0, v, 0.0))
        neg_sum = -jnp.sum(jnp.where(v < 0, v, 0.0))
        s = jnp.where(pos_sum >= neg_sum, 1.0, -1.0)
        keep = (jnp.sign(v) == s)
        mu = jnp.sum(jnp.abs(v) * keep) / jnp.maximum(keep.sum(), 1)
        # drop the minority-sign entries (their index slot points to 0 weight)
        idx = jnp.where(keep, idx, n)              # n => scatter-dropped
        return {"mu": mu * s, "idx": idx.astype(jnp.int32)}

    def decompress(self, payload, n):
        out = jnp.zeros((n + 1,), jnp.float32)
        out = out.at[payload["idx"]].set(payload["mu"])
        return out[:n]

    def wire_bits(self, n):
        return _k(n, self.fraction) * 32.0 + 32.0

    def entropy_bits(self, n):
        k = _k(n, self.fraction)
        idx_bits = math.log2(max(n / k, 2.0)) + 2
        return k * idx_bits + 32.0


class RandMask(Compressor):
    """CPFed [68]: random-mask sparsifier (unbiased, 1/p rescale) with optional
    Gaussian noise on survivors (differential privacy)."""
    biased = False

    def __init__(self, fraction=0.05, dp_sigma=0.0):
        self.fraction = fraction
        self.dp_sigma = dp_sigma
        self.name = f"randmask{fraction:g}"

    def _idx(self, seed_key, n):
        k = _k(n, self.fraction)
        # data-independent mask: pseudo-random permutation from a shared seed
        scores = jax.random.uniform(seed_key, (n,))
        _, idx = jax.lax.top_k(scores, k)
        return idx

    def compress(self, rng, x):
        n = x.shape[0]
        seed, noise = jax.random.split(rng)
        idx = self._idx(seed, n)
        vals = x[idx] / self.fraction
        if self.dp_sigma:
            vals = vals + self.dp_sigma * jax.random.normal(noise, vals.shape)
        return {"vals": vals, "seed": seed}

    def decompress(self, payload, n):
        idx = self._idx(payload["seed"], n)
        out = jnp.zeros((n,), jnp.float32)
        return out.at[idx].set(payload["vals"].astype(jnp.float32))

    def wire_bits(self, n):
        # indices are regenerated from the 64-bit seed — only values travel
        return _k(n, self.fraction) * 32.0 + 64.0


register("topk")(lambda fraction=0.01, **kw: TopK(fraction))
register("stc")(lambda fraction=0.01, **kw: STC(fraction))
register("sbc")(lambda fraction=0.01, **kw: SBC(fraction))
register("randmask")(lambda fraction=0.05, dp_sigma=0.0, **kw: RandMask(fraction, dp_sigma))
