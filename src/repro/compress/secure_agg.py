"""Privacy stages for the wire stack (DESIGN.md §11).

Two wrapping ``CommTransform``s close the ROADMAP "privacy-compatible wire
stack" item by riding the *existing* grammar, ledger and state threading —
privacy is a pipeline property here, not a side channel:

``secagg`` — secure-aggregation-shaped masking over the **integer code
domain** of a quantizing pipeline.  Each client adds a pairwise modular mask
to every integer payload plane (int8 QSGD levels, 2-bit-packed ternary
bytes, top-k indices, ...) before the plane crosses the collective, and the
cohort's masks cancel *exactly*:

    m_i = g(i) - g((i-1) mod C)   over  Z_{2^w}  (w = plane dtype width)

with ``g(e) = PRG(fold_in(mask_key, e))`` a full-entropy draw per ring edge.
The sum over any full cohort telescopes to 0 mod 2^w, so the *sum of masked
code planes equals the sum of clear code planes bit-for-bit* — no float
arithmetic is involved, only two's-complement adds that XLA defines as
wraparound.  Each client touches O(1) PRG draws (its two ring edges), the
mask shape equals the plane shape, and a masked uint8 plane still all-gathers
as uint8 — composition with the PR 7 packed wire formats is free.

The mask context (shared per-round key, client index, cohort size) travels
through ``FLState.comm_state`` like any pipeline state; every wire hop
(sim/async dispatch, star shard_map, hier edge, gossip mix) injects its own
(key, idx, cohort) via :func:`inject_mask_ctx` before encoding.  The context
also rides in the payload (``secagg_ctx``) so the aggregator can re-derive
and subtract the mask per client — the simulation stand-in for SecAgg's
key-agreement channel (Bonawitz et al.), exactly as UVeQ ships its dither
seed.  The 128 ctx bits per leaf are *not* billed to ``wire_bits`` (a real
deployment establishes keys out of band, amortised over rounds); the payload
therefore measures ``wire_bits/8 + CTX_BITS/8`` bytes, a relation the tests
pin down.

``secagg`` refuses float carriers: masking is a group operation over Z_{2^w},
and an f32 plane has no modular group to cancel in.  Chain a quantizing
carrier first (``"qsgd:4>>secagg"``, ``"topk:0.05>>qsgd:4>>secagg"``).

``dpnoise:<sigma>[,<clip>]`` — client-level DP at the wire boundary: ``clip``
bounds the L2 norm of the **whole per-client update** (all leaves jointly),
and each of the model's L leaves gets an equal share ``clip/sqrt(L)`` of
that budget (encode runs per leaf, so the split is how a per-leaf transform
realises a joint sensitivity bound).  Every leaf is then perturbed with
N(0, (sigma*clip)^2) — the Gaussian mechanism in noise-multiplier form over
the joint release — before the noised update reaches the inner pipeline.
The leaf count is bound by the engine at build time (:func:`bind_n_leaves`,
called from ``ledger_terms`` / the hier and gossip builders); unbound
standalone use defaults to L = 1, the single-leaf case where split and
no-split coincide.  The inner pipeline's rng stream is passed through
*unmodified*, so ``sigma=0, clip=inf`` is a bit-exact no-op.  Privacy
accounting is zCDP: the joint sensitivity is sqrt(sum_l (clip/sqrt(L))^2)
= clip and the noise std is sigma*clip, so rho = 1/(2 sigma^2) per client
per round — independent of the leaf count *because* the clip budget is
split, not by assumption.  rho threads through ``CommLedger`` (``dp_rho``)
by the same additive accumulation as bytes — zCDP composes additively, so
the running ledger *is* the privacy budget.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.api import CommTransform, Payload, PyTree

__all__ = ["SecAgg", "DPNoise", "PRIVACY_STAGES", "make_privacy_stage",
           "has_mask_ctx", "inject_mask_ctx", "drop_mask_ctx", "ring_mask",
           "mask_payload", "dropout_correction", "zcdp_epsilon",
           "bind_n_leaves", "MASK_TAG", "DP_TAG", "CTX_BITS"]

PRIVACY_STAGES = ("secagg", "dpnoise")

MASK_TAG = 0x5eca66      # folds the round key into the shared mask-key stream
DP_TAG = 0xd9015e        # folds the per-client rng into the DP noise stream
CTX_BITS = 128           # per-leaf secagg_ctx: key u32[2] + idx i32 + cohort i32

_PROBE_N = 4096          # carrier probe length for the construction-time guard


# ---------------------------------------------------------------------------
# Mask algebra over Z_{2^w}
# ---------------------------------------------------------------------------

def _edge_draw(key, edge, ref):
    """Full-entropy uniform draw over the unsigned group of ``ref``'s width
    for ring edge ``edge`` (traced or static)."""
    w = 8 * ref.dtype.itemsize
    return jax.random.bits(jax.random.fold_in(key, edge), ref.shape,
                           jnp.dtype(f"uint{w}"))


def ring_mask(key, idx, cohort, ref):
    """Client ``idx``'s pairwise mask m_i = g(i) - g((i-1) mod C) in the
    dtype of ``ref``.  Sum over idx = 0..C-1 telescopes to 0 mod 2^w.
    ``cohort < 2`` (including the uninjected zero context) yields a zero
    mask, so standalone pipeline use is transparently unmasked."""
    coh = jnp.maximum(jnp.asarray(cohort, jnp.int32), 1)
    i = jnp.asarray(idx, jnp.int32) % coh
    prev = (i + coh - 1) % coh
    m = _edge_draw(key, i, ref) - _edge_draw(key, prev, ref)
    m = jnp.where(coh >= 2, m, jnp.zeros_like(m))
    if m.dtype != ref.dtype:
        m = jax.lax.bitcast_convert_type(m, ref.dtype)
    return m


def _map_int_leaves(tree, fn):
    """Apply ``fn(plane_id, leaf)`` to every integer-dtype leaf, in the
    stable tree-flatten order (the plane id both sides of the wire agree on)."""
    leaves, treedef = jax.tree.flatten(tree)
    out = [fn(i, leaf)
           if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.integer) else leaf
           for i, leaf in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def mask_payload(body, key, idx, cohort, sign):
    """Add (sign=+1) or subtract (sign=-1) the ring mask on every integer
    plane of a payload tree.  Integer add/sub in XLA wraps two's-complement,
    which *is* the group operation of Z_{2^w} — cancellation is exact, never
    approximate.  Float side info (scales, mu) is left clear; it carries no
    per-coordinate information once the codes are masked."""
    def one(i, leaf):
        m = ring_mask(jax.random.fold_in(key, i), idx, cohort, leaf)
        return leaf + m if sign > 0 else leaf - m
    return _map_int_leaves(body, one)


def dropout_correction(key, drop_idx, cohort, template):
    """The dropped client's mask tree m_d over ``template``'s integer planes.

    Mask-recovery semantics (satellite: dropout-of-one): a code-plane sum
    over a cohort missing client d equals the clear sum *minus* m_d (the
    other C-1 masks telescope to -m_d), so adding this tree back restores
    bit-exactness — the simulation analogue of SecAgg's seed-recovery round.
    """
    def one(i, leaf):
        return ring_mask(jax.random.fold_in(key, i), drop_idx, cohort, leaf)
    return _map_int_leaves(template, one)


def zcdp_epsilon(rho, delta=1e-5):
    """Convert cumulative zCDP rho to (epsilon, delta)-DP."""
    rho = float(rho)
    if rho <= 0.0:
        return 0.0
    if not math.isfinite(rho):
        return float("inf")
    return rho + 2.0 * math.sqrt(rho * math.log(1.0 / delta))


# ---------------------------------------------------------------------------
# Mask-context threading helpers (used by the engine wire hops)
# ---------------------------------------------------------------------------

def has_mask_ctx(pipe) -> bool:
    """True if the pipeline contains a SecAgg stage anywhere (so a wire hop
    must inject (key, idx, cohort) into the comm state before encoding)."""
    if isinstance(pipe, SecAgg):
        return True
    stages = getattr(pipe, "stages", None)
    if stages is not None:
        return any(has_mask_ctx(s) for s in stages)
    inner = getattr(pipe, "inner", None)
    return has_mask_ctx(inner) if inner is not None else False


def bind_n_leaves(pipe, n_leaves: int) -> int:
    """Tell every DPNoise stage inside ``pipe`` how many parameter leaves
    the model it encodes has, so the per-leaf clip share ``clip/sqrt(L)``
    keeps the *joint* update sensitivity at ``clip`` (and the billed
    rho = 0.5/sigma^2 honest).  Engine builders call this once per build,
    before any trace; returns the number of stages bound."""
    n_leaves = int(n_leaves)
    if n_leaves < 1:
        raise ValueError(f"n_leaves must be >= 1, got {n_leaves}")
    if isinstance(pipe, DPNoise):
        pipe.n_leaves = n_leaves
        return 1 + bind_n_leaves(pipe.inner, n_leaves)
    bound = 0
    stages = getattr(pipe, "stages", None)
    if stages is not None:
        bound += sum(bind_n_leaves(s, n_leaves) for s in stages)
    inner = getattr(pipe, "inner", None)
    if inner is not None:
        bound += bind_n_leaves(inner, n_leaves)
    return bound


def inject_mask_ctx(state, key, idx, cohort):
    """Rewrite every SecAgg mask context in a comm-state tree (static Python
    recursion — structure is trace-time constant; key/idx/cohort may be
    traced).  States without a context pass through unchanged."""
    if isinstance(state, dict):
        out = {k: inject_mask_ctx(v, key, idx, cohort)
               for k, v in state.items()}
        if "mask_key" in out:
            out["mask_key"] = jnp.asarray(key, jnp.uint32)
            out["mask_idx"] = jnp.asarray(idx, jnp.int32).reshape(())
            out["mask_cohort"] = jnp.asarray(cohort, jnp.int32).reshape(())
        return out
    if isinstance(state, (tuple, list)):
        return type(state)(inject_mask_ctx(v, key, idx, cohort)
                           for v in state)
    return state


def drop_mask_ctx(state):
    """Strip SecAgg context entries from a comm-state tree, recovering the
    tree an *unmasked* pipeline would hold — the masked-vs-unmasked
    differential harness compares the survivors leaf-for-leaf."""
    if isinstance(state, dict):
        if "mask_key" in state:
            return drop_mask_ctx(state["inner"])
        return {k: drop_mask_ctx(v) for k, v in state.items()}
    if isinstance(state, (tuple, list)):
        return type(state)(drop_mask_ctx(v) for v in state)
    return state


# ---------------------------------------------------------------------------
# The stages
# ---------------------------------------------------------------------------

class SecAgg(CommTransform):
    """Pairwise-mask the integer code planes of ``inner``'s payload.

    Wrapping transform (like EF/DGC): decode re-derives the mask from the
    payload's ``secagg_ctx`` and subtracts it, so the aggregation layer's
    decode-per-client-then-weighted-mean structure needs no special cases,
    and a zero-weight (dropped-out) client can never corrupt the mean.
    Byte accounting delegates to ``inner`` unchanged — masking costs zero
    wire bytes — but ``entropy_bits`` collapses to ``wire_bits``: masked
    codes are uniform on Z_{2^w}, so the source papers' entropy coders can
    no longer compress them.  That loss is the honest price of masking and
    the tests pin it down.
    """

    carrier_key = None        # wrapping transform, not a chainable stage

    def __init__(self, inner: CommTransform):
        if has_mask_ctx(inner):
            raise ValueError("secagg is already in this pipeline; "
                             "masks are applied once, at the outermost "
                             "integer code domain")
        if inner.carrier_len(_PROBE_N) > 0:
            raise ValueError(
                f"secagg masks integer code domains, but {inner.name!r} "
                f"leaves a float32 carrier on the wire — chain a quantizing "
                f"carrier before secagg (e.g. 'qsgd:4>>secagg', "
                f"'topk:0.05>>qsgd:4>>secagg', 'ternary>>secagg')")
        self.inner = inner
        self.name = f"{inner.name}>>secagg"

    # masking changes neither bias nor backend/wire capabilities
    @property
    def biased(self):
        return self.inner.biased

    @property
    def kernel_capable(self):
        return self.inner.kernel_capable

    @property
    def wire(self):
        return self.inner.wire

    @property
    def backend(self):
        return self.inner.backend

    def init(self, shape):
        return {"mask_key": jnp.zeros((2,), jnp.uint32),
                "mask_idx": jnp.zeros((), jnp.int32),
                "mask_cohort": jnp.zeros((), jnp.int32),
                "inner": self.inner.init(shape)}

    def encode(self, state, rng, x):
        # the inner pipeline sees the rng stream unmodified — masked and
        # unmasked runs draw identical quantization randomness
        payload, ist = self.inner.encode(state["inner"], rng, x)
        key, idx, coh = (state["mask_key"], state["mask_idx"],
                         state["mask_cohort"])
        out = dict(mask_payload(payload, key, idx, coh, +1))
        out["secagg_ctx"] = {"key": key, "idx": idx, "cohort": coh}
        return out, dict(state, inner=ist)

    def decode(self, payload: Payload, n: int):
        p = dict(payload)
        ctx = p.pop("secagg_ctx")
        body = mask_payload(p, ctx["key"], ctx["idx"], ctx["cohort"], -1)
        return self.inner.decode(body, n)

    # --- byte accounting: ctx is the out-of-band key channel, unbilled ----
    def meta_bits(self, n):
        return self.inner.wire_bits(n)

    def meta_entropy_bits(self, n):
        return self.inner.wire_bits(n)   # masked planes are incompressible

    def dp_rho_per_round(self):
        return self.inner.dp_rho_per_round()


class DPNoise(CommTransform):
    """Client-level clip + Gaussian noise ahead of ``inner``'s encode.

    ``clip`` is the L2 budget of the WHOLE per-client update.  Encode runs
    per leaf, so each of the model's ``n_leaves`` leaves is clipped to its
    equal share ``clip/sqrt(n_leaves)`` and perturbed with std sigma*clip;
    the joint release is then one Gaussian mechanism with sensitivity
    sqrt(sum_l (clip/sqrt(L))^2) = clip and noise multiplier sigma, so rho
    per round is 1/(2 sigma^2) — leaf-count independent *because* the clip
    budget is split (without the split, L independently-clipped leaves
    would compose to L x 0.5/sigma^2).  ``n_leaves`` is bound by the
    engine via :func:`bind_n_leaves`; the default 1 is exact for
    single-leaf use, where split and no-split coincide.  State, decode and
    byte accounting are the inner pipeline's verbatim; with ``sigma == 0``
    and an infinite clip both branches vanish statically and the transform
    is a bit-exact no-op (the inner rng stream is untouched).
    """

    carrier_key = None

    def __init__(self, inner: CommTransform, sigma: float, clip: float = 1.0):
        sigma, clip = float(sigma), float(clip)
        if sigma < 0.0:
            raise ValueError(f"dpnoise sigma must be >= 0, got {sigma}")
        if clip <= 0.0:
            raise ValueError(f"dpnoise clip must be > 0 (use inf to disable "
                             f"clipping), got {clip}")
        if sigma > 0.0 and not math.isfinite(clip):
            raise ValueError("dpnoise with sigma > 0 needs a finite clip — "
                             "unbounded sensitivity has no DP guarantee")
        self.inner = inner
        self.sigma = sigma
        self.clip = clip
        self.n_leaves = 1            # rebound per model via bind_n_leaves
        self.name = f"{inner.name}>>dpnoise:{sigma:g}" + \
            (f",{clip:g}" if clip != 1.0 else "")

    @property
    def biased(self):
        return self.inner.biased

    @property
    def kernel_capable(self):
        return self.inner.kernel_capable

    @property
    def wire(self):
        return self.inner.wire

    @property
    def backend(self):
        return self.inner.backend

    def init(self, shape):
        return self.inner.init(shape)

    def encode(self, state, rng, x):
        y = x
        if math.isfinite(self.clip):
            # this leaf's equal share of the joint L2 budget: clipping each
            # of L leaves to clip/sqrt(L) bounds the whole update to clip
            leaf_clip = self.clip / math.sqrt(self.n_leaves)
            nrm = jnp.linalg.norm(y)
            y = y * jnp.minimum(1.0, leaf_clip / jnp.maximum(nrm, 1e-12))
        if self.sigma > 0.0:
            # std is sigma x the JOINT sensitivity (clip, not leaf_clip):
            # the L-leaf release is one Gaussian mechanism at rho=0.5/sigma^2
            z = jax.random.normal(jax.random.fold_in(rng, DP_TAG),
                                  y.shape, y.dtype)
            y = y + jnp.asarray(self.sigma * self.clip, y.dtype) * z
        return self.inner.encode(state, rng, y)

    def decode(self, payload, n):
        return self.inner.decode(payload, n)

    def meta_bits(self, n):
        return self.inner.wire_bits(n)

    def meta_entropy_bits(self, n):
        return self.inner.entropy_bits(n)

    def dp_rho_per_round(self):
        if self.sigma == 0.0:
            return self.inner.dp_rho_per_round()
        return 0.5 / (self.sigma * self.sigma) + \
            self.inner.dp_rho_per_round()


# ---------------------------------------------------------------------------
# Spec-grammar hook (consumed by api.make_compressor)
# ---------------------------------------------------------------------------

def make_privacy_stage(token: str, inner: CommTransform,
                       **kw) -> CommTransform:
    """Wrap ``inner`` with the privacy stage named by a spec token
    (``"secagg"``, ``"dpnoise:0.8"``, ``"dpnoise:0.8,1.0"``; a second ``:``
    is accepted as the clip separator)."""
    token = token.strip()
    if "@" in token:
        raise ValueError(
            f"privacy stage {token!r} takes no @suffix — put @kernel/@fused "
            f"on the carrier stages (e.g. 'ternary@fused>>secagg')")
    name, _, argstr = token.partition(":")
    name = name.strip()
    args = [float(a) for a in argstr.replace(":", ",").split(",")
            if a.strip()] if argstr else []
    if name == "secagg":
        if args:
            raise ValueError(f"secagg takes no args, got {token!r}")
        return SecAgg(inner)
    if name == "dpnoise":
        if not args:
            raise ValueError("dpnoise needs a sigma: 'dpnoise:<sigma>"
                             "[,<clip>]' (clip defaults to 1.0)")
        clip = args[1] if len(args) > 1 else float(kw.get("dp_clip", 1.0))
        return DPNoise(inner, args[0], clip)
    raise KeyError(f"unknown privacy stage {token!r}; have {PRIVACY_STAGES}")
