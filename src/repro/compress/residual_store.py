"""Bounded per-client pipeline state: the LRU slab + count-sketch tail.

The dense ``comm_state`` contract ((C,)-led state arrays, one row per
client) caps the simulated population at a few thousand clients — EF
residuals alone are O(C x model).  At survey scale (10^5–10^6 devices,
sub-percent cohorts) almost every row is cold at any moment, so the
``ResidualStore`` replaces the dense lead with a **slab of ``capacity``
slots** plus an id -> slot map:

  * ``gather(state, ids)``  — dispatch boundary: read the cohort's rows.
    Ids resident in the slab read their slot; absent ids read zeros
    (``eviction="drop"`` — EF restarts, the classic partial-participation
    approximation) or their count-sketch estimate (``eviction="sketch"`` —
    evicted mass survives, lossily, in a fixed-size hashed tail reusing the
    ``compress.sketch`` primitive).  Recovery is *energy-conserving*: the
    thresholded estimate is scaled by the least-squares projection of the
    tail onto its sketch before being handed out and removed, so a
    recover -> EF -> re-fold cycle is contractive — naive
    subtract-on-recover amplifies cross-client bucket collisions
    exponentially (see ``gather``).
  * ``scatter(state, ids, rows)`` — commit boundary (the wire hop in the
    sync engines, the *arrival* event in the AsyncEngine): write the
    cohort's advanced rows back.  Resident ids reuse their slot; new ids
    take free slots first, then evict the least-recently-committed
    occupants (whose rows fold into the tail under ``"sketch"``).

Degenerate contract (the bit-exactness anchor, tests/test_population.py):
with ``capacity >= C`` and every client touched in id order on first use,
slot i <-> client i, nothing is ever evicted, and gather/scatter are
value-identity — the engine arithmetic is bit-identical to the dense path.

State is a plain dict pytree (checkpointable, scan-carryable):

    {"slab":   tuple over param leaves of pipeline-state pytrees,
               every array (capacity,)-led,
     "client": (capacity,) int32 resident client id (-1 = free),
     "stamp":  (capacity,) int32 last-commit clock,
     "clock":  () int32,
     "tail":   [eviction="sketch" only] tuple over param leaves of
               (tail_rows, tail_cols) f32 sketches per float state array
               ((0,) placeholder for non-float arrays, e.g. the DGC
               warm-up round counter — those reset on re-entry)}

Memory is ``capacity x state-row + tails`` — flat in the population size,
which is the scale claim ``benchmarks --only scale`` measures.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.sketch import bucket_and_sign, hash_params

PyTree = Any

EVICTION_POLICIES = ("drop", "sketch")
_FREE = jnp.int32(-(2 ** 31))          # sort key: free slots first
_HIT = jnp.int32(2 ** 31 - 1)          # sort key: never evict a hit slot


def _state_templates(pipe, params):
    """Abstract per-leaf pipeline state pytrees (``pipe.init`` eval_shape),
    one per param leaf — the slab's row layout."""
    return tuple(jax.eval_shape(functools.partial(pipe.init, tuple(p.shape)))
                 for p in jax.tree.leaves(params))


def store_nbytes(state) -> int:
    """Concrete byte footprint of a store state (or any comm_state pytree) —
    the quantity the scale benchmark asserts flat in population size."""
    return int(sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(state)))


class ResidualStore:
    """Pure-function store ops for one (pipeline, params, capacity) binding.

    All methods are jit-traceable over the state dict; ``ids`` must be
    unique within one call (cohort sampling guarantees it — an affine
    coprime stride or a permutation slice)."""

    def __init__(self, pipe, params, capacity: int, eviction: str = "drop",
                 tail_rows: int = 5, tail_cols: int = 16384,
                 tail_seed: int = 23):
        if eviction not in EVICTION_POLICIES:
            raise ValueError(f"eviction must be one of {EVICTION_POLICIES}; "
                             f"got {eviction!r}")
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = int(capacity)
        self.eviction = eviction
        self.tail_rows = int(tail_rows)
        self.tail_cols = int(tail_cols)
        self.tail_seed = int(tail_seed)
        self.templates = _state_templates(pipe, params)

    # ------------------------------------------------------------------ init
    def init(self) -> dict:
        S = self.capacity
        state = {
            "slab": tuple(
                jax.tree.map(lambda a: jnp.zeros((S,) + a.shape, a.dtype),
                             tmpl) for tmpl in self.templates),
            "client": jnp.full((S,), -1, jnp.int32),
            "stamp": jnp.zeros((S,), jnp.int32),
            "clock": jnp.zeros((), jnp.int32),
        }
        if self.eviction == "sketch":
            state["tail"] = tuple(
                jax.tree.map(
                    lambda a: (jnp.zeros((self.tail_rows, self.tail_cols),
                                         jnp.float32)
                               if jnp.issubdtype(a.dtype, jnp.floating)
                               else jnp.zeros((0,), jnp.float32)), tmpl)
                for tmpl in self.templates)
        return state

    # ---------------------------------------------------------------- lookup
    @staticmethod
    def _match(state, ids):
        """(found (M,), slot (M,), eq (M, S)) — slot is garbage when !found
        and must stay masked."""
        eq = ids[:, None] == state["client"][None, :]
        return eq.any(axis=1), jnp.argmax(eq, axis=1), eq

    def _assign_slots(self, state, ids):
        """(found (M,), slot (M,)) — the slot each id commits to: hits
        reuse their slot, misses take free slots first, then the least-
        recently-committed occupied ones (the scatter contract; shared by
        ``scatter`` and the telemetry ``stats`` so the eviction preview
        cannot drift from the real assignment)."""
        S = self.capacity
        client, stamp = state["client"], state["stamp"]
        found, hit_slot, eq = self._match(state, ids)
        hit_slots = eq.any(axis=0)                             # (S,)
        key = jnp.where(hit_slots, _HIT,
                        jnp.where(client < 0, _FREE, stamp))
        order = jnp.argsort(key, stable=True)  # free, then LRU, hits last
        rank = jnp.cumsum((~found).astype(jnp.int32)) - 1
        slot = jnp.where(found, hit_slot,
                         order[jnp.clip(rank, 0, S - 1)])
        return found, slot

    def stats(self, state, ids):
        """Flight-recorder counters for one gather/scatter cycle over
        ``ids`` — a pure read (repro.obs.telemetry, DESIGN.md §12).

        ``hits`` / ``misses`` describe the gather; ``evictions`` previews
        the occupied slots the following scatter will fold out (misses
        landing on non-free slots under the same free-then-LRU
        assignment); ``sketch_recovered`` counts the missing rows gather
        answers from the tail estimate (every miss under the ``sketch``
        policy — thresholding may still zero unrecoverable coordinates —
        and 0 under ``drop``, where misses read zeros)."""
        found, slot = self._assign_slots(state, ids)
        miss = (~found).sum().astype(jnp.float32)
        evict = (~found) & (state["client"][slot] >= 0)
        return {
            "hits": found.sum().astype(jnp.float32),
            "misses": miss,
            "evictions": evict.sum().astype(jnp.float32),
            "sketch_recovered": (miss if self.eviction == "sketch"
                                 else jnp.float32(0.0)),
        }

    # ------------------------------------------------------------- tail hash
    def _coords(self, ids, n: int):
        """Global flat coordinates id*n + j in uint32 (wraparound feeds the
        multiplicative hash — aliasing across the 2^32 boundary is just one
        more hash collision for the sketch to absorb)."""
        j = jnp.arange(n, dtype=jnp.uint32)
        return ids.astype(jnp.uint32)[:, None] * jnp.uint32(n) + j[None, :]

    def _tail_add(self, tail, vals, ids, seed: int):
        """tail + sketch of M client rows ``vals`` (M, n) at their global
        coordinates.  Linear: rows zeroed by a mask contribute nothing."""
        a, b = hash_params(self.tail_rows, seed)
        coords = self._coords(ids, vals.shape[1])              # (M, n)

        def one(v, i):
            h, s = bucket_and_sign(i, a, b, self.tail_cols)    # (r, n)
            sx = s * v.astype(jnp.float32)[None, :]
            return jax.vmap(lambda hv, xv: jnp.zeros(
                self.tail_cols, jnp.float32).at[hv].add(xv))(h, sx)

        return tail + jax.vmap(one)(vals, coords).sum(0)

    def _tail_estimate(self, tail, ids, n: int, seed: int):
        """Median-of-rows recovery of M client rows (M, n) from the tail,
        with heavy-hitter thresholding: a count-sketch estimate carries
        ~sqrt(||tail||^2 / cols) collision noise per coordinate, so
        coordinates below that floor are unrecoverable and reading them
        back injects pure noise into the EF pipeline — they estimate to
        exactly 0 (making ``sketch`` degrade toward ``drop`` rather than
        toward divergence under fold pressure)."""
        a, b = hash_params(self.tail_rows, seed)
        coords = self._coords(ids, n)
        # 4-sigma floor: with ~n/cols coordinates per bucket, a lower floor
        # lets bucket noise masquerade as signal for EVERY coordinate in a
        # hot bucket, and the recover -> EF -> re-fold cycle amplifies it
        # exponentially (observed 100x/round at 2-sigma, 3 rows).
        floor = 4.0 * jnp.sqrt((tail ** 2).sum(axis=1).mean()
                               / self.tail_cols)

        def one(i):
            h, s = bucket_and_sign(i, a, b, self.tail_cols)
            est = s * jax.vmap(lambda Sr, hv: Sr[hv])(tail, h)
            med = jnp.median(est, axis=0)
            return jnp.where(jnp.abs(med) > floor, med, 0.0)

        return jax.vmap(one)(coords)

    def _tail_arrays(self, state):
        """Zipped flat (slab array, tail sketch, per-array seed) triples."""
        out = []
        for li, (slab_l, tail_l) in enumerate(zip(state["slab"],
                                                  state["tail"])):
            for ai, (sa, ta) in enumerate(zip(jax.tree.leaves(slab_l),
                                              jax.tree.leaves(tail_l))):
                out.append((li, ai, sa, ta,
                            self.tail_seed + 101 * li + 7 * ai))
        return out

    # ---------------------------------------------------------------- gather
    def gather(self, state, ids):
        """Rows for ``ids`` (M,) with an (M,) lead on every array.  Resident
        ids read their slot, absent ids read zeros (drop) or the tail
        estimate (sketch — the estimate is moved OUT of the tail and into
        the returned row).  Returns ``(rows, state)``; state changes only
        under the sketch policy."""
        found, slot, _ = self._match(state, ids)

        def take(a):
            rows = a[slot]
            keep = found.reshape((-1,) + (1,) * (rows.ndim - 1))
            return jnp.where(keep, rows, jnp.zeros_like(rows))

        rows = tuple(jax.tree.map(take, slab_l) for slab_l in state["slab"])
        if self.eviction != "sketch":
            return rows, state

        M = ids.shape[0]
        miss = (~found).astype(jnp.float32)
        flat_rows, rows_def = jax.tree.flatten(rows)
        new_rows = list(flat_rows)
        new_tails = {}
        offset = 0
        # walk (leaf, state-array) pairs in flatten order; the flatten order
        # of rows matches slab/tail (identical tuple-of-pytrees structure)
        for li, ai, _sa, ta, seed in self._tail_arrays(state):
            r_arr = new_rows[offset]
            if ta.size:
                n = int(np.prod(r_arr.shape[1:])) if r_arr.ndim > 1 else 1
                est = self._tail_estimate(ta, ids, n, seed)    # (M, n)
                est = est * miss[:, None]
                # Energy-conserving recovery: hand out gamma*est where
                # gamma projects the tail onto sketch(est).  Raw
                # subtract-on-recover AMPLIFIES — a heavy bucket hands its
                # mass to every colliding coordinate of every queried
                # client, and the recover -> EF -> re-fold cycle copies it
                # (observed 30-70x tail growth per round).  The projection
                # can only shrink ||tail||, and the energy handed out is
                # ~1/rows of the energy removed, so the cycle contracts.
                sk = self._tail_add(jnp.zeros_like(ta), est, ids, seed)
                gamma = jnp.clip((ta * sk).sum()
                                 / ((sk * sk).sum() + 1e-12), 0.0, 1.0)
                est = gamma * est
                new_rows[offset] = (r_arr
                                    + est.reshape(r_arr.shape)
                                    .astype(r_arr.dtype))
                new_tails[(li, ai)] = ta - gamma * sk
            offset += 1
        assert offset == len(flat_rows), "slab/tail structure drift"
        rows = jax.tree.unflatten(rows_def, new_rows)
        state = dict(state, tail=self._rebuild_tail(state, new_tails))
        return rows, state

    def _rebuild_tail(self, state, updates: dict):
        out = []
        for li, tail_l in enumerate(state["tail"]):
            leaves, tdef = jax.tree.flatten(tail_l)
            leaves = [updates.get((li, ai), t)
                      for ai, t in enumerate(leaves)]
            out.append(jax.tree.unflatten(tdef, leaves))
        return tuple(out)

    # --------------------------------------------------------------- scatter
    def scatter(self, state, ids, rows):
        """Commit the cohort's rows.  Hits reuse their slot; misses take free
        slots first, then the least-recently-committed occupied slots (one
        ``argsort`` over the per-slot sort key — free < stamp < hit).  The
        evicted occupants' rows fold into the tail under ``"sketch"`` and
        are dropped under ``"drop"``.  Requires ``capacity >= len(ids)``
        (enforced at engine build) so misses never land on a hit slot."""
        S = self.capacity
        M = ids.shape[0]
        if M > S:
            raise ValueError(f"cohort of {M} ids exceeds store capacity {S}")
        client, stamp = state["client"], state["stamp"]
        found, slot = self._assign_slots(state, ids)

        new_state = dict(state)
        if self.eviction == "sketch":
            old_ids = client[slot]                             # (M,)
            evict = ((~found) & (old_ids >= 0)).astype(jnp.float32)
            new_tails = {}
            for li, ai, sa, ta, seed in self._tail_arrays(state):
                if not ta.size:
                    continue
                vals = sa[slot].reshape(M, -1).astype(jnp.float32)
                vals = vals * evict[:, None]
                new_tails[(li, ai)] = self._tail_add(
                    ta, vals, jnp.maximum(old_ids, 0), seed)
            new_state["tail"] = self._rebuild_tail(state, new_tails)

        def put(a, r):
            return a.at[slot].set(r.astype(a.dtype))

        new_state["slab"] = tuple(
            jax.tree.map(put, slab_l, rows_l)
            for slab_l, rows_l in zip(state["slab"], rows))
        new_state["client"] = client.at[slot].set(ids.astype(jnp.int32))
        new_state["stamp"] = stamp.at[slot].set(state["clock"])
        new_state["clock"] = state["clock"] + 1
        return new_state

    # ----------------------------------------------------------------- specs
    def specs(self):
        """PartitionSpecs for the store state: fully replicated.  Slot count
        is decoupled from the mesh client axes (a slot hosts whichever
        client last committed), so unlike the dense ``comm_state_specs``
        lead there is no axis to pin rows to."""
        from jax.sharding import PartitionSpec as P
        return jax.tree.map(lambda a: P(*([None] * jnp.ndim(a))),
                            jax.eval_shape(self.init))
