"""llama4-scout-17b-a16e [moe] — 16 experts, top-1 routing, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E]. ~101B total params => FSDP over the
data axis; FL clients are whole pods (cross-silo)."""
import jax.numpy as jnp
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    num_experts=16, experts_per_token=1,
    block_pattern=("attn+moe",), rope_theta=5e5,
    dtype=jnp.bfloat16, fsdp=True, client_axis="pod",
    citation="[hf:meta-llama/Llama-4-Scout-17B-16E]",
)
SMOKE = CONFIG.reduced()
