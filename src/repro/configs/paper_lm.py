"""paper_lm — the paper-faithful small FL workload (CPU-runnable).

The survey's sources evaluate on small models (CNNs on CIFAR/FEMNIST, small
LSTMs); our equivalent is a ~1-4M-param transformer LM over the synthetic
non-iid bigram corpus (repro.data.synthetic). All convergence reproductions
(benchmarks/) run this config."""
import jax.numpy as jnp
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="paper_lm", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256,
    block_pattern=("attn+mlp",),
    dtype=jnp.float32, remat=False, fsdp=False, client_axis="data",
    citation="[McMahan et al. 2017 scale-equivalent]",
)
SMOKE = CONFIG
