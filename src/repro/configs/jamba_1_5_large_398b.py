"""jamba-1.5-large-398b [hybrid] — Mamba:attention 7:1 interleave, MoE every
other layer, 16e top-2 [arXiv:2403.19887]. 398B total => FSDP + pod clients.
Jamba uses d_state=16 mamba layers (mamba-1 sized state) — we instantiate the
SSD block with N=16."""
import jax.numpy as jnp
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    num_experts=16, experts_per_token=2,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    block_pattern=("mamba+mlp", "mamba+moe", "mamba+mlp", "attn+moe",
                   "mamba+mlp", "mamba+moe", "mamba+mlp", "mamba+moe"),
    dtype=jnp.bfloat16, fsdp=True, client_axis="pod",
    citation="[arXiv:2403.19887]",
)
SMOKE = CONFIG.reduced()
