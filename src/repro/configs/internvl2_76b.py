"""internvl2-76b [vlm] — InternViT (STUB frontend: 256 patch embeddings) +
InternLM2-76B-style decoder [arXiv:2404.16821]. ~70B params => FSDP, pod
clients."""
import jax.numpy as jnp
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, num_patches=256,
    block_pattern=("attn+mlp",), rope_theta=1e6,
    dtype=jnp.bfloat16, fsdp=True, client_axis="pod",
    citation="[arXiv:2404.16821]",
)
SMOKE = CONFIG.reduced()
