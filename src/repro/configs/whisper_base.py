"""whisper-base [audio] — enc-dec; conv/mel frontend is a STUB: input_specs
provides 1500 precomputed frame embeddings (B, 1500, 512) [arXiv:2212.04356].
The assignment exercises the transformer backbone only; decode_32k/long_500k
stress the decoder's KV-cache path far beyond Whisper's native 448-token
context — noted in EXPERIMENTS.md."""
import jax.numpy as jnp
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec",
    num_layers=6, encoder_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, frontend_tokens=1500,
    block_pattern=("attn+cross+mlp",),
    dtype=jnp.bfloat16, fsdp=False, client_axis="data",
    citation="[arXiv:2212.04356]",
)
SMOKE = CONFIG.reduced(frontend_tokens=16)
