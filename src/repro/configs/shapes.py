"""The four assigned input shapes + abstract input construction for the
dry-run (ShapeDtypeStruct stand-ins — weak-type-correct, shardable, no device
allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ArchConfig, ShapeConfig

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256,
                            mode="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32,
                               mode="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128,
                              mode="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1,
                             mode="decode"),
}

# sliding-window size used to make full-attention archs sub-quadratic for
# long_500k (DESIGN.md §3: the one shape where window attention substitutes)
LONG_WINDOW = 8_192


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def model_extras(cfg: ArchConfig, B: int, dtype) -> dict:
    """Modality-frontend stub inputs (the assignment's one allowed stub)."""
    out = {}
    if cfg.family == "vlm":
        out["patches"] = _sds((B, cfg.num_patches, cfg.d_model), dtype)
    if cfg.family == "encdec":
        out["frontend"] = _sds((B, cfg.frontend_tokens, cfg.d_model), dtype)
    return out


def train_input_specs(cfg: ArchConfig, shape: ShapeConfig, n_clients: int):
    """Client-major FL batch as ShapeDtypeStructs."""
    C = max(n_clients, 1)
    B = shape.global_batch // C
    assert B >= 1, (shape.name, C)
    S = shape.seq_len
    batch = {
        "tokens": _sds((C, B, S), jnp.int32),
        "labels": _sds((C, B, S), jnp.int32),
        "mask": _sds((C, B, S), jnp.float32),
        "sizes": _sds((C,), jnp.float32),
        "resources": _sds((C, 4), jnp.float32),
    }
    for k, v in model_extras(cfg, B, cfg.dtype).items():
        batch[k] = _sds((C,) + v.shape, v.dtype)
    return batch


def prefill_input_specs(cfg: ArchConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32)}
    batch.update(model_extras(cfg, B, cfg.dtype))
    return batch


def decode_cache_len(cfg: ArchConfig, shape: ShapeConfig) -> int:
    """KV-cache length for a decode shape. ``long_500k`` on full-attention
    archs uses the sliding-window ring buffer (bounded cache); SSM/hybrid
    attn layers keep the full-length cache (their memory is the SSM state /
    the rare attn layer)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return LONG_WINDOW
    return shape.seq_len


def decode_window(cfg: ArchConfig, shape: ShapeConfig) -> int:
    if cfg.sliding_window:
        return cfg.sliding_window
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return LONG_WINDOW
    return 0


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig,
                       quantized: bool = False):
    from repro.models.model import init_cache
    B = shape.global_batch
    cache_len = decode_cache_len(cfg, shape)
    enc_len = cfg.frontend_tokens if cfg.family == "encdec" else 0
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, cache_len, enc_len, quantized=quantized))
    token = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    return {"cache": cache, "token": token, "pos": pos}
