"""moonshot-v1-16b-a3b [dense spec, MoE 64e top-6 — Moonlight]
[hf:moonshotai/Moonlight-16B-A3B]. d_ff=1408 is per-expert."""
import jax.numpy as jnp
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163840,
    num_experts=64, experts_per_token=6,
    block_pattern=("attn+moe",), rope_theta=5e4,
    dtype=jnp.bfloat16, fsdp=False, client_axis="data",
    citation="[hf:moonshotai/Moonlight-16B-A3B]",
)
SMOKE = CONFIG.reduced()
