"""deepseek-67b [dense] — llama-arch, 95 layers [arXiv:2401.02954].
134 GB bf16 params => FSDP, pod clients."""
import jax.numpy as jnp
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=102400,
    block_pattern=("attn+mlp",), rope_theta=1e4,
    dtype=jnp.bfloat16, fsdp=True, client_axis="pod",
    citation="[arXiv:2401.02954]",
)
SMOKE = CONFIG.reduced()
