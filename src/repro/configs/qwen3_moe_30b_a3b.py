"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8 [hf:Qwen/Qwen3-30B-A3B].
d_ff=768 is the per-expert hidden size."""
import jax.numpy as jnp
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab_size=151936,
    num_experts=128, experts_per_token=8,
    block_pattern=("attn+moe",), rope_theta=1e6,
    dtype=jnp.bfloat16, fsdp=False, client_axis="data",
    citation="[hf:Qwen/Qwen3-30B-A3B]",
)
SMOKE = CONFIG.reduced()
