"""llama3.2-1b [dense] — small llama3, GQA kv=8, tied embeddings
[hf:meta-llama/Llama-3.2-1B]."""
import jax.numpy as jnp
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, tie_embeddings=True,
    block_pattern=("attn+mlp",), rope_theta=5e5,
    dtype=jnp.bfloat16, fsdp=False, client_axis="data",
    citation="[hf:meta-llama/Llama-3.2-1B]",
)
SMOKE = CONFIG.reduced()
