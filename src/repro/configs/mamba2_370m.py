"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060].
Attention-free: 48 Mamba-2 blocks, d_state=128, headdim=64."""
import jax.numpy as jnp
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    block_pattern=("mamba",),
    dtype=jnp.bfloat16, fsdp=False, client_axis="data",
    citation="[arXiv:2405.21060]",
)
SMOKE = CONFIG.reduced()
