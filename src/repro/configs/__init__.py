from repro.configs.registry import ARCH_IDS, all_archs, get_arch, get_smoke
from repro.configs.shapes import SHAPES
