"""qwen2.5-32b [dense] — GQA + QKV bias [hf:Qwen/Qwen2.5-0.5B family card,
scaled per assignment]."""
import jax.numpy as jnp
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=27648, vocab_size=152064, qkv_bias=True,
    block_pattern=("attn+mlp",), rope_theta=1e6,
    dtype=jnp.bfloat16, fsdp=False, client_axis="data",
    citation="[hf:Qwen/Qwen2.5-0.5B]",
)
SMOKE = CONFIG.reduced()
