"""--arch registry: one module per assigned architecture."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "qwen2_5_32b",
    "llama4_scout_17b_a16e",
    "qwen3_moe_30b_a3b",
    "mamba2_370m",
    "moonshot_v1_16b_a3b",
    "jamba_1_5_large_398b",
    "whisper_base",
    "llama3_2_1b",
    "internvl2_76b",
    "deepseek_67b",
    "paper_lm",          # the paper-faithful small FL config (CPU-runnable)
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIAS.update({
    "qwen2.5-32b": "qwen2_5_32b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-370m": "mamba2_370m",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-base": "whisper_base",
    "llama3.2-1b": "llama3_2_1b",
    "internvl2-76b": "internvl2_76b",
    "deepseek-67b": "deepseek_67b",
})


def get_arch(name: str):
    mod_name = _ALIAS.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke(name: str):
    mod_name = _ALIAS.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return getattr(mod, "SMOKE", mod.CONFIG.reduced())


def all_archs():
    return {i: get_arch(i) for i in ARCH_IDS if i != "paper_lm"}
