"""Federated training CLI — the deployment path (clients on mesh axes).

    PYTHONPATH=src python -m repro.launch.train --arch paper_lm \
        --rounds 20 --compressor qsgd8 [--hierarchical] [--devices 8]

Rounds run through the RoundEngine's scan driver (``run_rounds``): ``--chunk``
rounds are compiled into one donated-argument ``jax.lax.scan``, so the hot
path pays one dispatch per chunk instead of per round (``--chunk 1`` falls
back to per-round stepping for debugging). On real TPU hardware omit
--devices (uses the actual topology). On CPU, --devices N simulates an
N-device host for the mesh (set before jax init).
"""
import argparse
import os
import sys


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_lm")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--algorithm", default="fedavg")
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-lr", type=float, default=0.2)
    ap.add_argument("--compressor", default="none")
    ap.add_argument("--downlink", default="none")
    ap.add_argument("--backend", default="jax", choices=["jax", "kernel"],
                    help="encode/decode backend for every wire hop "
                         "(kernel = Pallas; interpret mode off-TPU)")
    ap.add_argument("--eval-every", type=int, default=0,
                    help="in-scan held-out-eval cadence in rounds "
                         "(FLConfig.eval_every); 0 = once per --chunk, "
                         "matching the pre-cadence host-side eval cost")
    ap.add_argument("--selection", default="all")
    ap.add_argument("--clients-per-round", type=int, default=0)
    ap.add_argument("--server-opt", default="fedavg")
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="AsyncEngine: virtual-clock buffered async FL "
                         "(DESIGN.md §7); --rounds then counts server "
                         "events (client uploads), not synchronous rounds")
    ap.add_argument("--clients", type=int, default=8,
                    help="async only: client slots (mesh-decoupled)")
    ap.add_argument("--buffer-size", type=int, default=0,
                    help="async FedBuff K (1 = FedAsync, 0 = n_clients "
                         "= the synchronous limit)")
    ap.add_argument("--staleness-alpha", type=float, default=0.5,
                    help="async staleness decay (1+tau)^(-alpha); also "
                         "scales the adaptive server-opt moments by the "
                         "flushed buffer's mean staleness (DESIGN.md §8)")
    ap.add_argument("--latency-profile", default="heavy_tail",
                    choices=["constant", "resource", "uniform", "heavy_tail"])
    ap.add_argument("--flush-deadline", type=float, default=0.0,
                    help="async adaptive buffer sizing: also flush when the "
                         "virtual clock passes the last flush + deadline "
                         "(0 = count-only FedBuff)")
    ap.add_argument("--population", type=int, default=0,
                    help="simulate this many clients via the streaming "
                         "ClientPopulation path (mesh-free; works with "
                         "--async too): per-round cohorts + bounded "
                         "residual store (DESIGN.md §9); e.g. "
                         "--population 1000000 --cohort 1024")
    ap.add_argument("--cohort", type=int, default=1024,
                    help="clients sampled per round (population mode)")
    ap.add_argument("--store-capacity", type=int, default=0,
                    help="residual-store slots (0 = min(population, "
                         "2 x cohort))")
    ap.add_argument("--eviction", default="drop",
                    choices=["drop", "sketch"],
                    help="residual-store eviction: drop the evicted "
                         "client's pipeline state, or fold it into the "
                         "count-sketch overflow tail")
    ap.add_argument("--scenario-trace", default="static",
                    choices=["static", "diurnal", "square"],
                    help="client availability trace (core.scenario, "
                         "DESIGN.md §13): static = i.i.d. Bernoulli, "
                         "square = phase-shifted duty windows, diurnal = "
                         "sinusoid-modulated Bernoulli")
    ap.add_argument("--scenario-period", type=float, default=24.0,
                    help="availability trace period, in rounds")
    ap.add_argument("--scenario-availability", type=float, default=1.0,
                    help="availability duty-cycle rate in (0, 1]; sets "
                         "both the dense selection hop's rate and "
                         "ClientPopulation.availability under --population")
    ap.add_argument("--scenario-dropout", type=float, default=0.0,
                    help="mid-round dropout hazard per unit virtual time; "
                         "dropped clients become zero-weight rows "
                         "(partial-update semantics, secagg-safe)")
    ap.add_argument("--scenario-epoch-scale", type=float, default=0.0,
                    help="heterogeneity-aware dispatch: floor in (0, 1] "
                         "of the per-client local-epoch scale (FedMCCS "
                         "capability latency); 0 disables")
    ap.add_argument("--scenario-deadline-quantile", type=float, default=0.0,
                    help="async adaptive deadline arming: flush deadline "
                         "tracks this completion-time quantile instead of "
                         "--flush-deadline; 0 disables")
    ap.add_argument("--scenario-seed", type=int, default=0,
                    help="seed for scenario phase/dropout draws")
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--batch-per-client", type=int, default=4)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU dry runs)")
    ap.add_argument("--model-parallel", type=int, default=2)
    ap.add_argument("--chunk", type=int, default=8,
                    help="rounds per compiled scan (run_rounds)")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="flight recorder (DESIGN.md §12): write a "
                         "schema-versioned JSONL trace here — span/event "
                         "records plus one machine-readable record per "
                         "round; implies FLConfig.telemetry. Render with "
                         "python -m repro.obs.report PATH")
    ap.add_argument("--profile-dir", default="", metavar="DIR",
                    help="with --trace: also wrap the run in "
                         "jax.profiler.trace(DIR) for TensorBoard/Perfetto")
    return ap.parse_args()


def main():
    args = _parse()
    if args.devices:
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    from repro import checkpoint
    from repro.configs.registry import get_arch
    from repro.core.engine import RoundRunner
    from repro.core.federated import make_fl_train_step
    from repro.core.hierarchical import make_hier_fl_train_step
    from repro.core.types import FLConfig
    from repro.data.synthetic import FedDataConfig, eval_batch, sample_round
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import Model, set_activation_mesh

    cfg = get_arch(args.arch)
    model = Model(cfg)
    eval_every = args.eval_every if args.eval_every > 0 else max(1, args.chunk)
    fl = FLConfig(algorithm=args.algorithm, local_steps=args.local_steps,
                  local_lr=args.local_lr, uplink_compressor=args.compressor,
                  downlink_compressor=args.downlink, backend=args.backend,
                  selection=args.selection,
                  clients_per_round=args.clients_per_round,
                  server_opt=args.server_opt, hierarchical=args.hierarchical,
                  sync_every=args.sync_every, eval_every=eval_every,
                  async_buffer_size=args.buffer_size,
                  staleness_alpha=args.staleness_alpha,
                  latency_profile=args.latency_profile,
                  async_flush_deadline=args.flush_deadline,
                  scenario_trace=args.scenario_trace,
                  scenario_period=args.scenario_period,
                  scenario_availability=args.scenario_availability,
                  scenario_dropout=args.scenario_dropout,
                  scenario_epoch_scale=args.scenario_epoch_scale,
                  scenario_deadline_quantile=args.scenario_deadline_quantile,
                  scenario_seed=args.scenario_seed,
                  telemetry=bool(args.trace))

    tracer = None
    if args.trace:
        from repro.obs.trace import Tracer
        topo_name = ("population" if args.population > 0 else
                     "async" if args.async_mode else
                     "hier" if args.hierarchical else "star")
        if args.population > 0 and args.async_mode:
            topo_name = "population-async"
        tracer = Tracer(args.trace, profile_dir=args.profile_dir,
                        meta=dict(arch=args.arch, topology=topo_name,
                                  rounds=args.rounds,
                                  compressor=args.compressor,
                                  algorithm=args.algorithm))

    def _save_checkpoint(params):
        if tracer is not None:
            with tracer.span("checkpoint", path=args.checkpoint):
                checkpoint.save(args.checkpoint, params)
        else:
            checkpoint.save(args.checkpoint, params)
        print("saved", args.checkpoint)

    def _emit_flush_events(ms):
        # host-derived async flush marks: one event per flushed generation
        if tracer is None or ms is None or "flushed" not in ms:
            return
        import numpy as np
        for i, v in enumerate(np.asarray(ms["flushed"])):
            if v > 0:
                tracer.event("flush", round=i)

    if args.population > 0:
        # mesh-free streaming-cohort path (DESIGN.md §9): --population
        # clients exist, --cohort train per round, per-client pipeline
        # state bounded by the residual store. Composes with --async
        # (slots = the cohort; --rounds counts server events).
        from repro.compress.residual_store import store_nbytes
        from repro.core.engine import (Topology, make_round_engine,
                                       run_rounds)
        from repro.core.population import ClientPopulation
        from repro.data.pipeline import cohort_data_fn

        N = args.population
        # one availability flag for both paths: the population keeps the
        # duty rate, the scenario (attached by the engine) shapes the trace
        pop = ClientPopulation(n_clients=N, cohort=min(args.cohort, N),
                               capacity=args.store_capacity,
                               eviction=args.eviction,
                               availability=args.scenario_availability)
        data = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=N,
                             seq_len=args.seq,
                             batch_per_client=args.batch_per_client,
                             heterogeneity=1.5)
        data_fn = cohort_data_fn(pop, data)
        topo = (Topology.async_(N) if args.async_mode else Topology.sim(N))
        engine = make_round_engine(model, fl, topo, chunk=args.seq,
                                   data_fn=data_fn, population=pop)
        state = engine.init_fn(jax.random.PRNGKey(0))
        mb = (store_nbytes(state.comm_state) / 1e6
              if state.comm_state is not None else 0.0)
        print(f"population={N:,} cohort={pop.cohort} "
              f"capacity={pop.capacity} eviction={pop.eviction} "
              f"store={mb:.1f}MB params={model.param_count():,} "
              f"{'async' if args.async_mode else 'sync'}")
        state, ms = run_rounds(engine, state, data_fn, args.rounds,
                               chunk=args.chunk, tracer=tracer)
        for i in range(args.rounds):
            led = jax.tree.map(lambda x, i=i: x[i], ms["ledger"])
            print(f"round {i:>4} loss={float(ms['loss'][i]):.3f} "
                  f"up={float(led.uplink_wire)/1e6:.2f}MB", flush=True)
        if tracer is not None:
            tracer.emit_rounds(ms, spec=engine.aux.get("telemetry"))
            _emit_flush_events(ms)
        if args.checkpoint:
            _save_checkpoint(state.params)
        if tracer is not None:
            tracer.close()
            print(f"trace: {args.trace} (render: python -m repro.obs.report "
                  f"{args.trace})")
        return

    if args.async_mode:
        # mesh-free virtual-clock path: --rounds counts server events
        from repro.core.async_engine import make_async_step
        from repro.core.engine import run_rounds
        data = FedDataConfig(vocab_size=cfg.vocab_size,
                             num_clients=args.clients, seq_len=args.seq,
                             batch_per_client=args.batch_per_client,
                             heterogeneity=1.5)

        def data_fn(v):
            return sample_round(data, jax.random.fold_in(
                jax.random.PRNGKey(1), v))

        a = make_async_step(model, fl, args.clients, data_fn, chunk=args.seq)
        print(f"async arch={cfg.name} clients={args.clients} "
              f"K={a.buffer_size} alpha={args.staleness_alpha} "
              f"profile={args.latency_profile} "
              f"deadline={args.flush_deadline or 'off'} "
              f"params={model.param_count():,}")
        state = a.init_fn(jax.random.PRNGKey(0))
        state, ms = run_rounds(a.engine, state, data_fn, args.rounds,
                               chunk=args.chunk, tracer=tracer)
        for i in range(args.rounds):
            led = jax.tree.map(lambda x, i=i: x[i], ms["ledger"])
            print(f"event {i:>4} t={float(ms['clock'][i]):8.2f} "
                  f"v={int(ms['server_version'][i]):>3} "
                  f"tau={float(ms['staleness'][i]):>3.0f} "
                  f"loss={float(ms['loss'][i]):.3f} "
                  f"up={float(led.uplink_wire)/1e6:.2f}MB", flush=True)
        if tracer is not None:
            tracer.emit_rounds(ms, spec=a.engine.aux.get("telemetry"))
            _emit_flush_events(ms)
        if args.checkpoint:
            _save_checkpoint(state.params)
        if tracer is not None:
            tracer.close()
            print(f"trace: {args.trace} (render: python -m repro.obs.report "
                  f"{args.trace})")
        return

    n = jax.device_count()
    mp = min(args.model_parallel, n)
    if args.hierarchical:
        mesh = make_host_mesh(model=mp, pod=2, data=n // (2 * mp))
    else:
        mesh = make_host_mesh(model=mp)
    set_activation_mesh(mesh)
    print(f"mesh={dict(mesh.shape)} arch={cfg.name} "
          f"params={model.param_count():,}")

    if args.hierarchical:
        step = make_hier_fl_train_step(model, fl, mesh, chunk=args.seq)
        G, Ce = step.n_pods, step.clients_per_pod
        C = G * Ce
    else:
        step = make_fl_train_step(model, fl, mesh, chunk=args.seq)
        C = step.n_clients
    state = step.init_fn(jax.random.PRNGKey(0))

    data = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=C,
                         seq_len=args.seq,
                         batch_per_client=args.batch_per_client,
                         heterogeneity=1.5)
    ev = eval_batch(data, jax.random.PRNGKey(99), batch_size=4)

    def data_fn(r):
        b = sample_round(data, jax.random.fold_in(jax.random.PRNGKey(1), r))
        if args.hierarchical:
            return {k: v.reshape((G, Ce) + v.shape[1:]) for k, v in b.items()
                    if k in ("tokens", "labels", "mask")}
        return b

    def global_params(params):
        return (jax.tree.map(lambda x: x[0], params) if args.hierarchical
                else params)

    def metrics_fn(state, m):
        # held-out eval INSIDE the compiled scan, gated to every
        # --eval-every-th round by the runner (FLConfig.eval_every)
        loss = model.loss(global_params(state.params), ev, chunk=args.seq)[0]
        return dict(m, eval_loss=loss)

    # ONE runner for the whole run — its compiled chunk scan is reused
    # across eval windows (one compilation per chunk shape)
    chunk = max(1, args.chunk)
    runner = RoundRunner(step.engine, data_fn, chunk=chunk,
                         metrics_fn=metrics_fn, tracer=tracer)
    import contextlib
    profile_cm = tracer.profile() if tracer is not None else \
        contextlib.nullcontext()
    done = 0
    with profile_cm:
        while done < args.rounds:
            k = min(chunk, args.rounds - done)
            state, ms = runner.run(state, k)
            for i in range(k):
                led = jax.tree.map(lambda x, i=i: x[i], ms["ledger"])
                print(f"round {done + i:>3} "
                      f"loss={float(ms['loss'][i]):.3f} "
                      f"up={float(led.uplink_wire)/1e6:.2f}MB "
                      f"ratio={float(led.compression_ratio()):.1f}x",
                      flush=True)
                ev_loss = float(ms["eval_loss"][i])
                if ev_loss == ev_loss:      # NaN on cadence-skipped rounds
                    print(f"eval@{done + i}: {ev_loss:.3f}", flush=True)
                    if tracer is not None:
                        tracer.event("eval", round=done + i, loss=ev_loss)
            if tracer is not None:
                # the stages naming record is written once, with the
                # first chunk's rounds
                tracer.emit_rounds(
                    ms, spec=(step.engine.aux.get("telemetry")
                              if done == 0 else None),
                    start_round=done)
            done += k
    if args.checkpoint:
        _save_checkpoint(global_params(state))
    if tracer is not None:
        tracer.close()
        print(f"trace: {args.trace} (render: python -m repro.obs.report "
              f"{args.trace})")


if __name__ == "__main__":
    main()
