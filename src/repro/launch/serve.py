"""Serving CLI: batched greedy decoding from a (trained or fresh) global
model — the downlink side of the FL story, and the driver behind the
decode_32k / long_500k dry-run shapes.

    PYTHONPATH=src python -m repro.launch.serve --arch paper_lm \
        --restore ckpt.npz --batch 4 --steps 32
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_lm")
    ap.add_argument("--restore", default="")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--window", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro import checkpoint
    from repro.configs.registry import get_arch, get_smoke
    from repro.models.model import Model

    cfg = get_arch(args.arch) if args.arch == "paper_lm" \
        else get_smoke(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.restore:
        params = checkpoint.restore(args.restore, params)

    B = args.batch
    rng = jax.random.PRNGKey(1)
    prompt = jax.random.randint(rng, (B, args.prompt_len), 0, cfg.vocab_size)
    enc_len = cfg.frontend_tokens if cfg.family == "encdec" else 0
    cache = model.init_cache(B, args.cache_len, enc_len=enc_len)
    step = jax.jit(lambda p, c, t, pos: model.decode(
        p, c, t, pos, window=args.window))

    # prefill token-by-token (simple reference path), then greedy decode;
    # per-step wall-clock (block_until_ready) feeds the decode telemetry
    # summary below — the first step is the jit compile and is reported
    # separately, not folded into the latency stats
    import time
    tok = prompt[:, :1]
    out = [tok]
    prefill_s, decode_s = [], []
    for t in range(args.prompt_len + args.steps - 1):
        t0 = time.perf_counter()
        logits, cache = step(params, cache, tok, jnp.int32(t))
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        (prefill_s if t + 1 < args.prompt_len else decode_s).append(dt)
        if t + 1 < args.prompt_len:
            tok = prompt[:, t + 1:t + 2]
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    seqs = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} served {B} seqs x {seqs.shape[1]} tokens")
    for b in range(min(B, 2)):
        print(f"  seq{b}:", " ".join(str(int(x)) for x in seqs[b][:40]))

    # ------------------------------------------------- decode telemetry
    def _stats(xs):
        if not xs:
            return 0.0, 0.0
        xs = sorted(xs)
        mean = sum(xs) / len(xs)
        p95 = xs[min(len(xs) - 1, int(0.95 * (len(xs) - 1) + 0.5))]
        return mean, p95

    compile_s = prefill_s[0] if prefill_s else \
        (decode_s[0] if decode_s else 0.0)
    warm_prefill = prefill_s[1:]
    warm_decode = decode_s if prefill_s else decode_s[1:]
    pf_mean, pf_p95 = _stats(warm_prefill)
    dc_mean, dc_p95 = _stats(warm_decode)
    toks = B * len(warm_decode)
    wall = sum(warm_decode)
    print(f"decode telemetry: compile+first_step={compile_s * 1e3:.1f}ms")
    print(f"  prefill: {len(warm_prefill)} steps "
          f"mean={pf_mean * 1e3:.2f}ms p95={pf_p95 * 1e3:.2f}ms "
          f"({sum(warm_prefill):.3f}s total)")
    print(f"  decode:  {len(warm_decode)} steps "
          f"mean={dc_mean * 1e3:.2f}ms p95={dc_p95 * 1e3:.2f}ms "
          f"({wall:.3f}s total)")
    if wall > 0:
        print(f"  throughput: {toks / wall:.1f} tokens/sec "
              f"(batch {B} x {len(warm_decode)} warm decode steps)")


if __name__ == "__main__":
    main()
