"""Serving CLI: batched greedy decoding from a (trained or fresh) global
model — the downlink side of the FL story, and the driver behind the
decode_32k / long_500k dry-run shapes.

    PYTHONPATH=src python -m repro.launch.serve --arch paper_lm \
        --restore ckpt.npz --batch 4 --steps 32
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_lm")
    ap.add_argument("--restore", default="")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--window", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro import checkpoint
    from repro.configs.registry import get_arch, get_smoke
    from repro.models.model import Model

    cfg = get_arch(args.arch) if args.arch == "paper_lm" \
        else get_smoke(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.restore:
        params = checkpoint.restore(args.restore, params)

    B = args.batch
    rng = jax.random.PRNGKey(1)
    prompt = jax.random.randint(rng, (B, args.prompt_len), 0, cfg.vocab_size)
    enc_len = cfg.frontend_tokens if cfg.family == "encdec" else 0
    cache = model.init_cache(B, args.cache_len, enc_len=enc_len)
    step = jax.jit(lambda p, c, t, pos: model.decode(
        p, c, t, pos, window=args.window))

    # prefill token-by-token (simple reference path), then greedy decode
    tok = prompt[:, :1]
    out = [tok]
    for t in range(args.prompt_len + args.steps - 1):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        if t + 1 < args.prompt_len:
            tok = prompt[:, t + 1:t + 2]
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    seqs = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} served {B} seqs x {seqs.shape[1]} tokens")
    for b in range(min(B, 2)):
        print(f"  seq{b}:", " ".join(str(int(x)) for x in seqs[b][:40]))


if __name__ == "__main__":
    main()
