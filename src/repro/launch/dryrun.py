"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination against the production mesh, and extract the roofline terms
from the compiled artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod1
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
      --shape train_4k --mesh pod2 --fl qsgd8

Results land in experiments/dryrun/<mesh>/<fl>/<arch>__<shape>.json and are
the single source for EXPERIMENTS.md §Dry-run and §Roofline.

NOTE: the XLA_FLAGS line below MUST execute before any other jax-importing
module — jax locks the device count at first init.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCH_IDS, get_arch
from repro.configs import shapes as shp
from repro.core.types import FLConfig
from repro.core.federated import make_fl_train_step
from repro.core.hierarchical import make_hier_fl_train_step
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as shd
from repro.models.model import Model, set_activation_mesh

FL_VARIANTS = {
    # paper-faithful baseline: FedAvg/FedSGD with f32 updates on the wire
    "baseline": FLConfig(algorithm="fedsgd", local_steps=1,
                         uplink_compressor="none"),
    # FedPAQ/QSGD quantised uplink + LFL quantised downlink
    "qsgd8": FLConfig(algorithm="fedsgd", local_steps=1,
                      uplink_compressor="qsgd8", downlink_compressor="lfl8"),
    # STC sparse-ternary with error feedback
    "stc": FLConfig(algorithm="fedsgd", local_steps=1,
                    uplink_compressor="stc", topk_fraction=0.01),
    # top-k + error feedback, FedAdam server
    "topk": FLConfig(algorithm="fedsgd", local_steps=1,
                     uplink_compressor="topk", topk_fraction=0.01,
                     server_opt="fedadam", server_lr=0.05),
    # hierarchical (pod2 only; this program is the edge step — the cloud
    # step is a second compiled program). §Perf finding: the edge hop rides
    # ICI where uncompressed psum beats C x int8 gather (see A1), so
    # compression is applied to the cross-pod (DCN) hop only — exactly
    # Hier-Local-QSGD's placement.
    "hier": FLConfig(algorithm="fedavg", local_steps=1, hierarchical=True,
                     uplink_compressor="none", pod_compressor="qsgd8",
                     sync_every=4),
    # combined-scheme pipeline (CommPipeline tentpole): top-k support with
    # QSGD-quantised values — strictly fewer wire bytes than either stage
    # alone; EF residual rides in FLState.comm_state
    "topk_qsgd": FLConfig(algorithm="fedsgd", local_steps=1,
                          uplink_compressor="topk:0.01>>qsgd:8"),
    # DGC: momentum-corrected sparsification (momentum_correction wrapper)
    "dgc": FLConfig(algorithm="fedsgd", local_steps=1,
                    uplink_compressor="topk", topk_fraction=0.01,
                    dgc_momentum=0.9),
    # beyond-paper: uncompressed but bf16 deltas on the wire
    "bf16delta": FLConfig(algorithm="fedsgd", local_steps=1,
                          uplink_compressor="none", delta_dtype="bf16"),
    # beyond-paper combo: quantized wire + bf16 residual path
    "qsgd8_bf16": FLConfig(algorithm="fedsgd", local_steps=1,
                           uplink_compressor="qsgd8",
                           downlink_compressor="lfl8", delta_dtype="bf16"),
}


# ---------------------------------------------------------------------------
# sharding builders for serve-path inputs
# ---------------------------------------------------------------------------

def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def cache_spec_tree(cache_abs, cfg, mesh, kv_seq_shard=False):
    """kv_seq_shard: shard the cache *sequence* dim over the model axis
    (flash-decode style partial attention; §Perf pair-B optimization) instead
    of splitting heads/head_dim — avoids the resharding XLA otherwise does
    around the attention dots when KV heads don't divide the model axis."""
    sizes = dict(mesh.shape)
    msize = sizes.get("model", 1)
    dp = _dp_axes(mesh)
    dsize = int(np.prod([sizes[a] for a in dp])) if dp else 1

    def leaf_spec(path, leaf):
        key = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        shape = leaf.shape
        bspec = None
        if key in ("k", "v", "ek", "ev", "kscale", "vscale"):
            nsb, B, L, KV, hd = shape
            if B % dsize == 0 and B >= dsize:
                bspec = dp
                lspec = None
            elif L % dsize == 0 and L >= dsize:
                lspec = dp
            else:
                lspec = None
            if kv_seq_shard and lspec is None and L % msize == 0 \
                    and L >= msize:
                return P(None, bspec, "model", None, None)
            if KV % msize == 0:
                return P(None, bspec, lspec, "model", None)
            if hd % msize == 0:
                return P(None, bspec, lspec, None, "model")
            return P(None, bspec, lspec, None, None)
        if key == "state":
            nsb, B, H, N, Pd = shape
            if B % dsize == 0 and B >= dsize:
                bspec = dp
            return P(None, bspec, "model" if H % msize == 0 else None,
                     None, None)
        if key == "conv":
            nsb, B, W, Cd = shape
            if B % dsize == 0 and B >= dsize:
                bspec = dp
            return P(None, bspec, None, "model" if Cd % msize == 0 else None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_abs)


# ---------------------------------------------------------------------------
# builders: (lowered, n_devices, note) per mode
# ---------------------------------------------------------------------------

CHUNK = 512


def build_train(cfg, shape_cfg, mesh, fl: FLConfig):
    model = Model(cfg)
    if fl.hierarchical:
        step = make_hier_fl_train_step(model, fl, mesh, chunk=CHUNK)
        G, Ce = step.n_pods, step.clients_per_pod
        C = G * Ce
        batch = shp.train_input_specs(cfg, shape_cfg, C)
        # reshape client dim (C,..) -> (G,Ce,..)
        batch = {k: jax.ShapeDtypeStruct((G, Ce) + v.shape[1:], v.dtype)
                 for k, v in batch.items() if k != "resources"}
        bshard = {k: NamedSharding(mesh, P("pod", "data"))
                  for k in batch}
        state_abs = jax.eval_shape(step.init_fn,
                                   jax.ShapeDtypeStruct((2,), jnp.uint32))
        fn = jax.jit(step.step_edge,
                     in_shardings=(step.state_shardings, bshard))
        return fn.lower(state_abs, batch), f"hier edge step C={C}"
    step = make_fl_train_step(model, fl, mesh, chunk=CHUNK)
    batch = shp.train_input_specs(cfg, shape_cfg, step.n_clients)
    state_abs = jax.eval_shape(step.init_fn,
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
    fn = jax.jit(step.step_fn,
                 in_shardings=(step.state_shardings,
                               step.batch_sharding_fn(batch)))
    return fn.lower(state_abs, batch), f"fl train C={step.n_clients}"


def build_prefill(cfg, shape_cfg, mesh):
    model = Model(cfg)
    pspecs = shd.tree_specs(model.abstract_params(), model.logical_axes(),
                            mesh, cfg.fsdp)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    batch = shp.prefill_input_specs(cfg, shape_cfg)
    dp = _dp_axes(mesh)
    dsize = int(np.prod([dict(mesh.shape)[a] for a in dp]))
    B = shape_cfg.global_batch
    bspec = P(dp) if B % dsize == 0 else P()
    bshard = {k: NamedSharding(mesh, bspec) for k in batch}
    fn = jax.jit(lambda p, b: model.prefill(p, b, window=cfg.sliding_window,
                                            chunk=CHUNK),
                 in_shardings=(pshard, bshard))
    return fn.lower(model.abstract_params(), batch), "prefill"


def build_decode(cfg, shape_cfg, mesh, kv_seq_shard=False,
                 kv_int8=False):
    model = Model(cfg)
    pspecs = shd.tree_specs(model.abstract_params(), model.logical_axes(),
                            mesh, cfg.fsdp)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    specs = shp.decode_input_specs(cfg, shape_cfg, quantized=kv_int8)
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          cache_spec_tree(specs["cache"], cfg, mesh,
                                          kv_seq_shard=kv_seq_shard),
                          is_leaf=lambda x: isinstance(x, P))
    dp = _dp_axes(mesh)
    dsize = int(np.prod([dict(mesh.shape)[a] for a in dp]))
    B = shape_cfg.global_batch
    tshard = NamedSharding(mesh, P(dp) if B % dsize == 0 and B >= dsize
                           else P())
    w = shp.decode_window(cfg, shape_cfg)
    fn = jax.jit(lambda p, c, t, pos: model.decode(p, c, t, pos, window=w),
                 in_shardings=(pshard, cshard, tshard,
                               NamedSharding(mesh, P())))
    cache_len = shp.decode_cache_len(cfg, shape_cfg)
    return fn.lower(model.abstract_params(), specs["cache"], specs["token"],
                    specs["pos"]), f"decode cache_len={cache_len} window={w}"


# ---------------------------------------------------------------------------
# model-flops accounting (the "useful compute" numerator)
# ---------------------------------------------------------------------------

def active_params(model: Model) -> tuple:
    """(total, active-per-token) parameter counts (MoE-aware)."""
    import numpy as _np
    cfg = model.cfg
    total, active = 0, 0
    for path, d in jax.tree_util.tree_flatten_with_path(
            model.defs, is_leaf=lambda x: hasattr(x, "logical"))[0]:
        n = int(_np.prod(d.shape))
        total += n
        keys = [str(getattr(p, "key", p)) for p in path]
        if "experts" in d.logical:
            e, k = cfg.num_experts, max(cfg.experts_per_token, 1)
            active += n * k // e
        elif "embed" == keys[-1] or "lm_head" == keys[-1]:
            active += 0        # embeddings are lookups, lm_head counted once
        else:
            active += n
    return total, active


def model_flops(model: Model, shape_cfg) -> float:
    total, active = active_params(model)
    if shape_cfg.mode == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * active * tokens
    if shape_cfg.mode == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape_cfg.global_batch      # decode: 1 token/seq


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_one(arch: str, shape_name: str, mesh_name: str, fl_name: str,
            out_dir: str, force=False, no_remat=False,
            kv_seq_shard=False, kv_int8=False, tag="") -> dict:
    out_path = os.path.join(out_dir, mesh_name, fl_name,
                            f"{arch}__{shape_name}{tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)

    cfg = get_arch(arch)
    if no_remat:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, remat=False)
    shape_cfg = shp.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    set_activation_mesh(mesh)
    n_dev = mesh.size

    rec = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
           "fl": fl_name, "devices": n_dev, "ok": False,
           "no_remat": no_remat, "kv_seq_shard": kv_seq_shard}
    t0 = time.time()
    try:
        if shape_cfg.mode == "train":
            lowered, note = build_train(cfg, shape_cfg, mesh,
                                        FL_VARIANTS[fl_name])
        elif shape_cfg.mode == "prefill":
            lowered, note = build_prefill(cfg, shape_cfg, mesh)
        else:
            lowered, note = build_decode(cfg, shape_cfg, mesh,
                                         kv_seq_shard=kv_seq_shard,
                                         kv_int8=kv_int8)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "peak_gb": getattr(mem, "peak_memory_in_bytes", 0) / 1e9,
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):       # pre-0.5 jax returns [dict]
            ca = ca[0] if ca else {}
        rec["xla_cost"] = {"flops": ca.get("flops", 0.0),
                           "bytes": ca.get("bytes accessed", 0.0)}

        stats = hlo.analyze(compiled.as_text())
        # Memory term: XLA's fusion-aware per-visit bytes, corrected for while
        # trip counts via the flops ratio (XLA cost analysis counts loop
        # bodies once; flops give the exact correction on the same loops).
        # stats.hbm_bytes (instruction-level sum) is kept as an upper bound.
        corr = max(1.0, stats.flops / ca["flops"]) if ca.get("flops") else 1.0
        hbm_est = ca.get("bytes accessed", 0.0) * corr
        stats_est = dataclasses.replace(stats, hbm_bytes=hbm_est) \
            if hbm_est else stats
        terms = hlo.roofline(stats_est)
        model = Model(cfg)
        mf = model_flops(model, shape_cfg) / n_dev
        total, active = active_params(model)
        rec.update({
            "note": note,
            "params_total": total, "params_active": active,
            "hlo_flops_per_dev": stats.flops,
            "hbm_bytes_per_dev": hbm_est or stats.hbm_bytes,
            "hbm_bytes_upper": stats.hbm_bytes,
            "trip_corr": corr,
            "coll_bytes_per_dev": stats.coll_bytes,
            "coll_client_bytes": stats.coll_client_bytes,
            "coll_model_bytes": stats.coll_model_bytes,
            "coll_by_type": stats.coll_by_type,
            "coll_count": stats.coll_count,
            "roofline": terms,
            "dominant": hlo.dominant(terms),
            "model_flops_per_dev": mf,
            "useful_flops_ratio": (mf / stats.flops) if stats.flops else 0.0,
            "ok": True,
        })
    except Exception as e:  # noqa
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK " if rec["ok"] else "FAIL"
    print(f"[{status}] {mesh_name}/{fl_name}/{arch}/{shape_name} "
          f"({rec['total_s']}s) "
          + (f"dom={rec.get('dominant')} coll={rec.get('coll_bytes_per_dev', 0)/1e6:.1f}MB"
             if rec["ok"] else rec.get("error", "")), flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--fl", default="baseline", choices=list(FL_VARIANTS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--kv-seq-shard", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--fsdp-legacy", action="store_true",
                    help="pre-C1 FSDP placement (contraction-dim data shard)")
    ap.add_argument("--chunk", type=int, default=512,
                    help="attention/xent chunk size (§Perf A5)")
    ap.add_argument("--tag", default="",
                    help="output-filename suffix for §Perf experiments")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    global CHUNK
    CHUNK = args.chunk
    if args.fsdp_legacy:
        shd.FSDP_MODE = "legacy"
    archs = [a for a in ARCH_IDS if a != "paper_lm"] \
        if (args.all or not args.arch) else [args.arch]
    shapes = list(shp.SHAPES) if (args.all or not args.shape) else [args.shape]
    fails = 0
    for a in archs:
        for s in shapes:
            rec = run_one(a, s, args.mesh, args.fl, args.out, args.force,
                          no_remat=args.no_remat,
                          kv_seq_shard=args.kv_seq_shard,
                          kv_int8=args.kv_int8, tag=args.tag)
            fails += 0 if rec["ok"] else 1
    print(f"done; {fails} failures")
    return fails


if __name__ == "__main__":
    raise SystemExit(main())
