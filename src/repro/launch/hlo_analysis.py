"""Trip-count-aware roofline terms from compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies **once**, which
under-counts scan-over-layers models by ~num_layers x (verified in
EXPERIMENTS.md §Dry-run notes). This module re-derives the three roofline
inputs directly from ``compiled.as_text()`` with loop trip counts applied:

  * flops            — 2·|out|·K per ``dot`` (contraction size K from operand
                       shapes), x trip counts. Elementwise flops are ignored
                       (transformer compute is >97% dot-shaped; documented).
  * hbm bytes        — Σ (result + operand) buffer bytes over *materialised*
                       top-level instructions (post-fusion HLO materialises
                       only fusion results; fusion internals are free), x trips.
                       An upper-ish proxy: buffer reuse isn't modelled.
  * collective bytes — per collective op, wire bytes per device:
                       all-gather: result;  all-reduce: 2·result (ring);
                       reduce-scatter: operand;  all-to-all: result;
                       collective-permute: result.  x trips.

Trip counts: for each ``while``, the largest integer ``constant(N)`` in its
condition computation (loop bounds dominate; induction starts are 0/1).
Everything is per-device (the text is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->")
_OPCODE_RE = re.compile(r"^\s*(?:\(.*?\)|\S+)\s+([\w\-]+)\(")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    dims = [int(d) for d in dims.split(",")] if dims else []
    return dt, dims


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    text: str
    operands: list


def parse_computations(hlo: str):
    """-> {comp_name: [Instr]}; also per-comp instr type map."""
    comps, cur, cur_name = {}, None, None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip()) if "{" in line else None
        if hdr and not line.lstrip().startswith("%param"):
            cur_name = hdr.group(1)
            cur = []
            comps[cur_name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        om = _OPCODE_RE.match(rest)
        opcode = om.group(1) if om else ""
        # type string = everything before the opcode token
        tpos = rest.find(opcode + "(") if opcode else -1
        type_str = rest[:tpos] if tpos > 0 else rest
        operands = re.findall(r"(%[\w.\-]+)", rest[tpos:]) if tpos > 0 else []
        cur.append(Instr(name, type_str, opcode, rest, operands))
    return comps


def _trip_count(cond_instrs) -> int:
    best = 1
    for ins in cond_instrs:
        for c in re.findall(r"constant\((\d+)\)", ins.text):
            best = max(best, int(c))
    return best


def _group_stride(text: str) -> int:
    """Stride between the first two members of the first replica group
    (1 for contiguous/model-axis groups; >= |model| for client-axis)."""
    m = re.search(r"replica_groups=\{\{(\d+),(\d+)", text)
    if m:
        return abs(int(m.group(2)) - int(m.group(1)))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
                  text)
    if not m:
        return 1
    g, s, dims, perm = m.groups()
    import numpy as _np
    dims = [int(d) for d in dims.split(",")]
    arr = _np.arange(int(_np.prod(dims))).reshape(dims)
    if perm:
        arr = arr.transpose([int(p) for p in perm.split(",")])
    arr = arr.reshape(int(g), int(s))
    if arr.shape[1] < 2:
        return 1
    return int(abs(arr[0, 1] - arr[0, 0]))


def _dot_flops(ins: Instr, types: dict) -> float:
    _, out_dims = _shape_elems(ins.type_str)
    out_n = math.prod(out_dims) if out_dims else 1
    mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.text)
    if not mdims or not ins.operands:
        return 2.0 * out_n                      # fallback
    lhs = types.get(ins.operands[0])
    if lhs is None:
        return 2.0 * out_n
    _, lhs_dims = _shape_elems(lhs)
    k = 1
    for d in (mdims.group(1).split(",") if mdims.group(1) else []):
        di = int(d)
        if di < len(lhs_dims):
            k *= lhs_dims[di]
    return 2.0 * out_n * k


@dataclasses.dataclass
class HLOStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_client_bytes: float = 0.0   # strided replica groups = client (data/
                                     # pod) axis: the FL aggregation wire
    coll_model_bytes: float = 0.0    # contiguous groups = model (TP) axis
    coll_by_type: dict = dataclasses.field(default_factory=dict)
    coll_count: int = 0

    def add(self, other, mult=1.0):
        self.flops += mult * other.flops
        self.hbm_bytes += mult * other.hbm_bytes
        self.coll_bytes += mult * other.coll_bytes
        self.coll_client_bytes += mult * other.coll_client_bytes
        self.coll_model_bytes += mult * other.coll_model_bytes
        self.coll_count += int(mult * other.coll_count)
        for k, v in other.coll_by_type.items():
            self.coll_by_type[k] = self.coll_by_type.get(k, 0.0) + mult * v


def analyze(hlo_text: str) -> HLOStats:
    comps = parse_computations(hlo_text)
    types_per_comp = {c: {i.name: i.type_str for i in instrs}
                      for c, instrs in comps.items()}
    memo = {}

    def comp_stats(cname: str) -> HLOStats:
        if cname in memo:
            return memo[cname]
        memo[cname] = HLOStats()            # cycle guard
        st = HLOStats()
        types = types_per_comp.get(cname, {})
        for ins in comps.get(cname, []):
            if ins.opcode == "dot":
                st.flops += _dot_flops(ins, types)
            coll = next((c for c in _COLLECTIVES
                         if ins.opcode.startswith(c)), None)
            if coll:
                rb = _shape_bytes(ins.type_str)
                wire = {"all-reduce": 2 * rb, "all-gather": rb,
                        "reduce-scatter": 0.0, "all-to-all": rb,
                        "collective-permute": rb}[coll]
                if coll == "reduce-scatter":
                    ops_b = sum(_shape_bytes(types.get(o, ""))
                                for o in ins.operands)
                    wire = ops_b
                st.coll_bytes += wire
                st.coll_count += 1
                st.coll_by_type[coll] = st.coll_by_type.get(coll, 0.0) + wire
                # axis attribution: model is the minor-most mesh axis, so a
                # collective whose group members stride by >= |model| runs
                # over the client (data/pod) axes — the FL wire. Group
                # geometry is reconstructed exactly from either the explicit
                # `{{0,16,...}}` list or the `[G,S]<=[dims]T(perm)` iota form.
                if _group_stride(ins.text) >= 16:
                    st.coll_client_bytes += wire
                else:
                    st.coll_model_bytes += wire
            # ---- recurse into called computations -------------------------
            mwhile = re.search(r"condition=(%[\w.\-]+), body=(%[\w.\-]+)",
                               ins.text)
            if mwhile:
                # while: children fully counted x trips; the while op itself
                # aliases its carry — no HBM bytes of its own.
                cond, body = mwhile.groups()
                trips = _trip_count(comps.get(cond, []))
                st.add(comp_stats(body), trips)
                st.add(comp_stats(cond), trips)
                continue
            called = None
            for attr in ("calls", "to_apply"):
                mcall = re.search(attr + r"=(%[\w.\-]+)", ins.text)
                if mcall:
                    called = mcall.group(1)
            mbr = re.search(r"branch_computations=\{([^}]*)\}", ins.text)
            branches = (re.findall(r"%[\w.\-]+", mbr.group(1))
                        if mbr else [])
            if ins.opcode in ("call", "conditional", "async-start"):
                for b in ([called] if called else []) + branches:
                    st.add(comp_stats(b), 1.0)
                continue
            if called:
                # fusion / reduce / map bodies: their flops+collectives are
                # real, but their internals never touch HBM — only the fusion
                # op's own operands/results do (counted below).
                child = comp_stats(called)
                st.flops += child.flops
                st.coll_bytes += child.coll_bytes
                st.coll_count += child.coll_count
                for k, v in child.coll_by_type.items():
                    st.coll_by_type[k] = st.coll_by_type.get(k, 0.0) + v

            # ---- HBM proxy -------------------------------------------------
            if ins.opcode in ("parameter", "constant", "tuple",
                              "get-tuple-element", "bitcast", "iota",
                              "after-all", "partition-id", "replica-id"):
                continue
            if ins.opcode in ("dynamic-slice", "slice", "gather"):
                st.hbm_bytes += 2 * _shape_bytes(ins.type_str)   # read+write
            elif ins.opcode in ("dynamic-update-slice", "scatter"):
                upd = (types.get(ins.operands[1], "")
                       if len(ins.operands) > 1 else "")
                st.hbm_bytes += 2 * _shape_bytes(upd)            # in-place
            elif ins.opcode == "broadcast":
                st.hbm_bytes += (_shape_bytes(ins.type_str)
                                 + sum(_shape_bytes(types.get(o, ""))
                                       for o in ins.operands))
            else:
                st.hbm_bytes += _shape_bytes(ins.type_str)
                st.hbm_bytes += sum(_shape_bytes(types.get(o, ""))
                                    for o in ins.operands)
        memo[cname] = st
        return st

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+(%[\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    return comp_stats(entry) if entry else HLOStats()


# ----------------------------------------------------- stage cross-checking

def name_stage_mismatch(stage_names, stage_table, measured: float,
                        expected_total: float = None,
                        rtol: float = 0.02) -> str:
    """Explain a collective-bytes mismatch in pipeline-stage terms.

    ``stage_names`` / ``stage_table`` come from the flight recorder's
    ``TelemetrySpec`` (``repro.obs.telemetry``, whose per-stage byte tables
    sum to the ledger's wire total by construction); ``measured`` is what
    the HLO actually moved (e.g. all-gather bytes over the client axis) and
    ``expected_total`` what the ledger bills (defaults to ``sum(table)``).
    Returns "" when they agree within ``rtol``; otherwise a message naming
    the stage whose byte share best explains the gap — the first thing to
    look at when a wire change breaks the HLO==ledger claim."""
    expected = (float(sum(stage_table)) if expected_total is None
                else float(expected_total))
    gap = measured - expected
    if expected > 0 and abs(gap) <= rtol * expected:
        return ""
    if not stage_table:
        return (f"collective bytes mismatch: measured {measured:.0f} vs "
                f"expected {expected:.0f} (no stage table to attribute)")
    # the stage whose byte weight is closest to the gap magnitude is the
    # most likely culprit (a stage dropped from / double-counted on the
    # wire); ties go to the largest share
    best = min(range(len(stage_table)),
               key=lambda i: (abs(abs(gap) - float(stage_table[i])),
                              -float(stage_table[i])))
    share = (100.0 * float(stage_table[best]) / expected if expected
             else 0.0)
    direction = "missing from" if gap < 0 else "over-counted on"
    return (f"collective bytes mismatch: measured {measured:.0f} vs "
            f"expected {expected:.0f} (gap {gap:+.0f}); closest stage: "
            f"'{stage_names[best]}' ({float(stage_table[best]):.0f}B/unit, "
            f"{share:.0f}% of the wire) — likely {direction} the "
            f"collective")


# ------------------------------------------------------------------ roofline

V5E = {"flops_bf16": 197e12, "hbm_gbps": 819e9, "ici_gbps": 50e9}


def roofline(stats: HLOStats, hw=V5E) -> dict:
    return {
        "compute_s": stats.flops / hw["flops_bf16"],
        "memory_s": stats.hbm_bytes / hw["hbm_gbps"],
        "collective_s": stats.coll_bytes / hw["ici_gbps"],
    }


def dominant(terms: dict) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k]).replace("_s", "")
