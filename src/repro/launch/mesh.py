"""Production mesh construction (TPU v5e; 256 chips/pod).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick to work.
"""
from __future__ import annotations

import jax

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (data=FL clients, model=TP) or 2x16x16 two-pod
    (pod=edge hierarchy / cross-silo clients). Uses a device subset when the
    dry-run host exposes more placeholder devices than the mesh needs."""
    import numpy as np
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    return make_mesh(shape, axes, devices=jax.devices()[:n])


def make_host_mesh(model: int = 1, data: int | None = None, pod: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    if data is None:
        data = n // (model * pod)
    shape = (pod, data, model) if pod > 1 else (data, model)
    axes = ("pod", "data", "model") if pod > 1 else ("data", "model")
    return make_mesh(shape, axes)
