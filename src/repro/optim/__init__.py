from repro.optim.sgd import sgd, apply_updates
from repro.optim.adamw import adamw
