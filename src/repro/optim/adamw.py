"""AdamW (pure JAX) — centralized-baseline optimizer for the examples and
the one-shot-FL ensemble teacher."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw(lr: float, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0):
    def init(params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)
        upd = jax.tree.map(
            lambda mh_, vh_, p: -lr * (mh_ / (jnp.sqrt(vh_) + eps)
                                       + weight_decay * p.astype(jnp.float32)),
            mh, vh, params)
        return upd, {"m": m, "v": v, "t": t}

    return init, update
