"""Minimal client/centralized optimizers (pure JAX, optax-free)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd(lr: float, momentum: float = 0.0):
    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params):
        if momentum == 0.0:
            upd = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
            return upd, state
        m = jax.tree.map(lambda m_, g: momentum * m_ + g.astype(jnp.float32),
                         state["m"], grads)
        return jax.tree.map(lambda m_: -lr * m_, m), {"m": m}

    return init, update


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)
