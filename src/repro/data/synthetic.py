"""Synthetic *non-iid* federated LM data (statistical heterogeneity, RSQ1).

Each client samples token streams from its own bigram process

    T_c = softmax( G + beta_c · P_{z_c} )

where G is a shared global bigram structure, P_z are per-cluster perturbation
matrices, and z_c ~ Dirichlet-ish cluster assignment. ``heterogeneity`` (the
Dirichlet-style knob; 0 = iid) scales beta — at high values the per-client
conditionals diverge sharply, reproducing the non-iid regime where the survey's
claims live (SCAFFOLD's client drift [46], STC's non-iid robustness [39],
FL+HC's client clustering [43]).

Device *resource profiles* (CPU / memory / energy / link quality ∈ [0,1]) are
also generated per client — the FedMCCS [50] / FedCS [52] selection signal.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FedDataConfig:
    vocab_size: int
    num_clients: int
    seq_len: int
    batch_per_client: int
    heterogeneity: float = 1.0     # 0 => iid clients (cluster-level skew)
    client_skew: float = 1.0       # per-client unigram skew multiplier
                                   # (0 => heterogeneity is purely cluster-
                                   # structured; the FL+HC recovery setting)
    num_clusters: int = 4
    seed: int = 0


@functools.partial(jax.jit, static_argnames=("cfg",))
def _client_tables(cfg: FedDataConfig):
    kg, kp, kz, kr, ks, ku = jax.random.split(jax.random.PRNGKey(cfg.seed), 6)
    V = min(cfg.vocab_size, 256)   # generator works over a core vocab
    G = jax.random.normal(kg, (V, V)) * 1.5
    P = jax.random.normal(kp, (cfg.num_clusters, V, V)) * 2.0
    z = jax.random.randint(kz, (cfg.num_clients,), 0, cfg.num_clusters)
    beta = cfg.heterogeneity
    # cluster-level transition skew + per-client unigram (label-distribution)
    # skew — the two classic non-iid axes (feature and label heterogeneity)
    gamma = jax.random.normal(ku, (cfg.num_clients, V)) * 1.5 * cfg.client_skew
    logits = G[None] + beta * (P[z] + gamma[:, None, :])  # (C, V, V)
    resources = jax.random.uniform(kr, (cfg.num_clients, 4), minval=0.05)
    sizes = 1.0 + jax.random.uniform(ks, (cfg.num_clients,))
    return logits, resources, sizes


def client_clusters(cfg: FedDataConfig):
    """Ground-truth generator cluster assignment per client (for FL+HC
    recovery experiments)."""
    kz = jax.random.split(jax.random.PRNGKey(cfg.seed), 6)[2]
    return jax.random.randint(kz, (cfg.num_clients,), 0, cfg.num_clusters)


def _token_stream(lg, r, B, S, V):
    """One client's (B, S) token batch from its bigram logits ``lg``."""
    k0, kseq = jax.random.split(r)
    first = jax.random.randint(k0, (B,), 0, V)

    def step(tok, k):
        nxt = jax.random.categorical(k, lg[tok], axis=-1)
        return nxt, nxt
    _, toks = jax.lax.scan(step, first, jax.random.split(kseq, S))
    return toks.T                                        # (B, S)


@functools.partial(jax.jit, static_argnames=("cfg",))
def sample_round(cfg: FedDataConfig, rng):
    """One round's client-major batch:
    tokens/labels/mask (C, B, S), sizes (C,), resources (C, 4)."""
    logits, resources, sizes = _client_tables(cfg)
    V = logits.shape[-1]
    C, B, S = cfg.num_clients, cfg.batch_per_client, cfg.seq_len
    rngs = jax.random.split(rng, C)
    tokens = jax.vmap(lambda lg, r: _token_stream(lg, r, B, S, V))(
        logits, rngs)                                    # (C, B, S)
    labels = jnp.roll(tokens, -1, axis=-1)
    mask = jnp.ones((C, B, S), jnp.float32).at[:, :, -1].set(0.0)
    return {"tokens": tokens, "labels": labels, "mask": mask,
            "sizes": sizes, "resources": resources}


@functools.partial(jax.jit, static_argnames=("cfg",))
def sample_cohort(cfg: FedDataConfig, rng, ids):
    """A cohort's batch at O(M), never materializing the population.

    ``_client_tables`` draws every per-client quantity as a (C,)-shaped
    array, which is exactly what a 10^6-client population cannot afford.
    Here each client's generator state derives from ``fold_in(key, id)``
    instead — same global G/P structure, O(1) in ``cfg.num_clients`` —
    so the streaming engines sample only the M cohort rows.  The per-id
    draws are deterministic in (seed, id) and independent of the round
    rng, matching the dense tables' round-invariance (the property the
    async degenerate-equivalence proof leans on), but the realized values
    differ from ``_client_tables``: this is the scale path, not a
    drop-in replica of the dense one.

    Returns the ``sample_round`` dict with an (M,) lead plus ``"ids"``."""
    kg, kp, kz, kr, ks, ku = jax.random.split(jax.random.PRNGKey(cfg.seed), 6)
    V = min(cfg.vocab_size, 256)
    B, S = cfg.batch_per_client, cfg.seq_len
    G = jax.random.normal(kg, (V, V)) * 1.5
    P = jax.random.normal(kp, (cfg.num_clusters, V, V)) * 2.0
    beta = cfg.heterogeneity

    def per_client(i):
        z = jax.random.randint(jax.random.fold_in(kz, i), (), 0,
                               cfg.num_clusters)
        gamma = jax.random.normal(jax.random.fold_in(ku, i),
                                  (V,)) * 1.5 * cfg.client_skew
        res = jax.random.uniform(jax.random.fold_in(kr, i), (4,),
                                 minval=0.05)
        size = 1.0 + jax.random.uniform(jax.random.fold_in(ks, i), ())
        lg = G + beta * (P[z] + gamma[None, :])
        toks = _token_stream(lg, jax.random.fold_in(rng, i), B, S, V)
        return toks, size, res

    tokens, sizes, resources = jax.vmap(per_client)(ids)
    labels = jnp.roll(tokens, -1, axis=-1)
    mask = jnp.ones(tokens.shape, jnp.float32).at[:, :, -1].set(0.0)
    return {"tokens": tokens, "labels": labels, "mask": mask,
            "sizes": sizes, "resources": resources,
            "ids": ids.astype(jnp.int32)}


def eval_batch(cfg: FedDataConfig, rng, batch_size=32):
    """A held-out batch from the SAME generator tables (same seed), flattened
    across clients — fresh samples via rng, evaluating the global model on
    the full client mixture."""
    b = sample_round(dataclasses.replace(cfg, batch_per_client=batch_size),
                     jax.random.fold_in(rng, 10_000))
    return {k: (v.reshape((-1,) + v.shape[2:]) if v.ndim >= 3 else v)
            for k, v in b.items() if k in ("tokens", "labels", "mask")}
