"""Host-side federated batch pipeline.

Wraps ``synthetic.sample_round`` into an iterator that device_puts each
round's client-major batch with the right NamedSharding (clients over the
client mesh axes). For multi-host deployment the same iterator runs per host
with ``jax.make_array_from_process_local_data``; on the dry-run host a plain
``device_put`` suffices.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax

from repro.data.synthetic import FedDataConfig, sample_round


class FederatedLoader:
    def __init__(self, cfg: FedDataConfig, shardings=None):
        self.cfg = cfg
        self.shardings = shardings
        self._rng = jax.random.PRNGKey(cfg.seed + 1)

    def __iter__(self) -> Iterator[dict]:
        while True:
            self._rng, sub = jax.random.split(self._rng)
            batch = sample_round(self.cfg, sub)
            if self.shardings is not None:
                batch = {k: jax.device_put(v, self.shardings[k])
                         for k, v in batch.items()}
            yield batch

    def round(self, i: int) -> dict:
        batch = sample_round(self.cfg, jax.random.fold_in(
            jax.random.PRNGKey(self.cfg.seed + 1), i))
        if self.shardings is not None:
            batch = {k: jax.device_put(v, self.shardings[k])
                     for k, v in batch.items()}
        return batch
