"""Host-side federated batch pipeline.

Wraps ``synthetic.sample_round`` into an iterator that device_puts each
round's client-major batch with the right NamedSharding (clients over the
client mesh axes). For multi-host deployment the same iterator runs per host
with ``jax.make_array_from_process_local_data``; on the dry-run host a plain
``device_put`` suffices.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp

from repro.data.synthetic import FedDataConfig, sample_cohort, sample_round

LATENCY_PROFILES = ("constant", "resource", "uniform", "heavy_tail")


def cohort_data_fn(population, cfg: FedDataConfig):
    """``data_fn(round_idx)`` over a :class:`ClientPopulation`: samples the
    round's cohort ids (pure in (population.seed, round_idx) — the engine
    recomputes the identical ids) and materializes only those M clients'
    batches via ``sample_cohort``, O(cohort) regardless of ``cfg
    .num_clients``.  The batch carries ``"ids"`` so commit-side consumers
    (the residual store, the async slot table) key state by client id."""
    def fn(round_idx):
        ids = population.cohort_ids(round_idx)
        return sample_cohort(
            cfg, jax.random.fold_in(
                jax.random.PRNGKey(cfg.seed + 1), round_idx), ids)
    return fn


def capability_latency(resources):
    """The deterministic FedMCCS capability base: compute + transfer time
    ``0.5/cpu + 0.5/link`` per client, no jitter.  This is the noise-free
    core of every non-constant ``device_latency`` profile, and the signal
    the scenario pack (``core.scenario``) keys mid-round dropout hazards
    and heterogeneity-aware local-epoch scaling on — one formula, so the
    async latency model and the scenario capability model cannot drift."""
    cpu = jnp.maximum(resources[:, 0], 0.05)
    link = jnp.maximum(resources[:, 3], 0.05)
    return (0.5 / cpu + 0.5 / link).astype(jnp.float32)


def device_latency(profile: str, resources, rng):
    """Per-client virtual round latency from the FedMCCS device profile.

    ``resources`` is the (C, 4) [cpu, memory, energy, link] array the data
    pipeline already generates per client (synthetic.sample_round) — the same
    signal FedMCCS selection gates on.  The AsyncEngine draws one latency per
    *dispatch* (DESIGN.md §7), so the profile's randomness models per-round
    jitter on top of the client's fixed capability:

      * ``constant``   — 1.0 for everyone (the degenerate limit in which the
                         AsyncEngine reproduces synchronous FedAvg);
      * ``resource``   — compute + transfer time, deterministic per client:
                         0.5/cpu + 0.5/link;
      * ``uniform``    — resource base x U[0.5, 1.5) jitter;
      * ``heavy_tail`` — resource base x Pareto(a=1.5) jitter (infinite
                         variance: the straggler regime where async buys its
                         time-to-target win).
    """
    C = resources.shape[0]
    if profile == "constant":
        return jnp.ones((C,), jnp.float32)
    base = capability_latency(resources)
    if profile == "resource":
        return base
    if profile == "uniform":
        return base * jax.random.uniform(rng, (C,), jnp.float32, 0.5, 1.5)
    if profile == "heavy_tail":
        u = jax.random.uniform(rng, (C,), jnp.float32, 1e-4, 1.0)
        return base * u ** (-1.0 / 1.5)
    raise ValueError(
        f"unknown latency profile {profile!r}; have {LATENCY_PROFILES}")


class FederatedLoader:
    def __init__(self, cfg: FedDataConfig, shardings=None):
        self.cfg = cfg
        self.shardings = shardings
        self._rng = jax.random.PRNGKey(cfg.seed + 1)

    def __iter__(self) -> Iterator[dict]:
        while True:
            self._rng, sub = jax.random.split(self._rng)
            batch = sample_round(self.cfg, sub)
            if self.shardings is not None:
                batch = {k: jax.device_put(v, self.shardings[k])
                         for k, v in batch.items()}
            yield batch

    def round(self, i: int) -> dict:
        batch = sample_round(self.cfg, jax.random.fold_in(
            jax.random.PRNGKey(self.cfg.seed + 1), i))
        if self.shardings is not None:
            batch = {k: jax.device_put(v, self.shardings[k])
                     for k, v in batch.items()}
        return batch
