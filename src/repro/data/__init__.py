from repro.data.synthetic import FedDataConfig, sample_round, eval_batch
from repro.data.pipeline import FederatedLoader
