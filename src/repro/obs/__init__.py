"""Flight recorder (DESIGN.md §12) — three layers over one run:

  * :mod:`repro.obs.telemetry` — in-graph ``RoundStats``: fixed-shape f32
    per-round telemetry (per-stage wire byte attribution, staleness
    histogram, buffer occupancy, residual-store counters, selection /
    availability counts) carried next to the ``CommLedger`` through every
    topology's metrics, gated by ``FLConfig.telemetry``;
  * :mod:`repro.obs.trace` — host-side tracer: versioned JSONL span/event
    sink (compile, chunk execute, eval, async flush, checkpoint) plus the
    opt-in ``jax.profiler`` hook around ``run_rounds`` chunks;
  * :mod:`repro.obs.report` — ``python -m repro.obs.report run.jsonl``:
    terminal / markdown run summary (byte waterfall, staleness histogram,
    time breakdown, claims-ready rows).

The package import is lazy on purpose: ``trace`` and ``report`` are
stdlib-only (jax loads only inside the helpers that need it), so the report
CLI runs anywhere the JSONL file does — importing :mod:`repro.obs` must not
drag jax in.
"""
_LAZY = {
    "RoundStats": "telemetry", "TelemetrySpec": "telemetry",
    "round_stats": "telemetry", "telemetry_spec": "telemetry",
    "stage_byte_table": "telemetry", "staleness_hist": "telemetry",
    "zero_stats": "telemetry", "STALENESS_EDGES": "telemetry",
    "N_STALENESS_BUCKETS": "telemetry",
    "Tracer": "trace", "SCHEMA_VERSION": "trace",
    "validate_file": "trace", "validate_record": "trace",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f"repro.obs.{_LAZY[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
