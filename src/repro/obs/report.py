"""Run-report renderer for flight-recorder traces (DESIGN.md §12).

    PYTHONPATH=src python -m repro.obs.report run.jsonl [--md report.md]

Reads a schema-v1 JSONL trace (repro.obs.trace), validates it, and renders
a terminal summary — per-stage byte waterfall, staleness histogram, time
breakdown, eval-cadence series (gaps print as ``-``), and a claims-ready
``metric,value`` block — optionally also written as markdown.  Stdlib only.
"""
from __future__ import annotations

import argparse
import sys

from repro.obs.trace import validate_file

_BAR = 28


def _fmt_bytes(b: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("kB", 1e3)):
        if abs(b) >= div:
            return f"{b / div:.2f}{unit}"
    return f"{b:.0f}B"


def _bar(frac: float) -> str:
    n = max(0, min(_BAR, round(frac * _BAR)))
    return "#" * n + "." * (_BAR - n)


def summarize(records: list) -> dict:
    """Fold the record stream into one summary dict (pure data — render
    below turns it into text)."""
    meta = records[0]
    spans, flushes = {}, 0
    stages_up, stages_down = [], []
    rounds = []
    for r in records[1:]:
        if r.get("type") == "span":
            k = r["kind"]
            cnt, tot = spans.get(k, (0, 0.0))
            spans[k] = (cnt + 1, tot + float(r["dur_s"]))
        elif r["kind"] == "stages":
            stages_up, stages_down = r.get("up", []), r.get("down", [])
        elif r["kind"] == "round":
            rounds.append(r["m"])
        elif r["kind"] == "flush" or (r.get("type") == "event"
                                      and r["kind"] == "flush"):
            flushes += 1

    def col(name):
        return [m.get(name) for m in rounds]

    def vecsum(name):
        out = None
        for m in rounds:
            v = m.get(name)
            if not isinstance(v, list):
                continue
            vals = [0.0 if x is None else float(x) for x in v]
            out = vals if out is None else [a + b for a, b in zip(out, vals)]
        return out or []

    def scalarsum(name):
        return sum(float(x) for x in col(name) if x is not None)

    up = vecsum("round_stats.up_stage_bytes")
    down = vecsum("round_stats.down_stage_bytes")
    series = {}
    for name in sorted({k for m in rounds for k in m}):
        vals = col(name)
        if any(isinstance(v, list) for v in vals):
            continue
        if any(v is None for v in vals) and any(v is not None for v in vals):
            series[name] = vals          # cadence-gapped metric
    return {
        "meta": meta,
        "n_rounds": len(rounds),
        "spans": spans,
        "flushes": flushes,
        "stages_up": stages_up,
        "stages_down": stages_down,
        "up_stage_bytes": up,
        "down_stage_bytes": down,
        "staleness_hist": vecsum("round_stats.staleness_hist"),
        "uplink_wire": scalarsum("ledger.uplink_wire"),
        "downlink_wire": scalarsum("ledger.downlink_wire"),
        "uplink_dense": scalarsum("ledger.uplink_dense"),
        "loss": [v for v in col("loss") if v is not None],
        "gapped": series,
        "store": {k: scalarsum(f"round_stats.store_{k}")
                  for k in ("hits", "misses", "evictions",
                            "sketch_recovered")},
    }


def render(s: dict, md: bool = False) -> str:
    h1 = (lambda t: f"# {t}") if md else (lambda t: f"== {t} ==")
    h2 = (lambda t: f"## {t}") if md else (lambda t: f"-- {t} --")
    out = []
    meta = {k: v for k, v in s["meta"].items()
            if k not in ("v", "kind", "schema", "ts")}
    out.append(h1("run report"))
    out.append(" ".join(f"{k}={v}" for k, v in sorted(meta.items()))
               or "(no run metadata)")
    out.append(f"rounds recorded: {s['n_rounds']}")

    # ---------------------------------------------------------- byte waterfall
    out.append("")
    out.append(h2("uplink byte waterfall (per stage, whole run)"))
    names = s["stages_up"] or [f"stage[{i}]"
                               for i in range(len(s["up_stage_bytes"]))]
    total = sum(s["up_stage_bytes"]) or 1.0
    if s["up_stage_bytes"]:
        w = max(len(n) for n in names)
        for n, b in zip(names, s["up_stage_bytes"]):
            out.append(f"  {n:<{w}}  {_fmt_bytes(b):>10}  "
                       f"{_bar(b / total)} {100.0 * b / total:5.1f}%")
    else:
        out.append("  (no RoundStats rows — run with FLConfig.telemetry "
                   "/ --trace)")
    dn = sum(s["down_stage_bytes"]) if s["down_stage_bytes"] else 0.0
    out.append(f"  uplink total {_fmt_bytes(sum(s['up_stage_bytes']))}  "
               f"downlink total {_fmt_bytes(dn)}")
    if s["uplink_dense"] and s["uplink_wire"]:
        out.append(f"  compression vs dense f32: "
                   f"{s['uplink_dense'] / s['uplink_wire']:.1f}x")

    # ------------------------------------------------------ staleness histogram
    hist = s["staleness_hist"]
    if hist and sum(hist) > 0:
        out.append("")
        out.append(h2("staleness histogram (async arrivals)"))
        edges = [0, 1, 2, 4, 8, 16, 32, 64]
        tot = sum(hist)
        for i, c in enumerate(hist):
            lo = edges[i]
            hi = f"<{edges[i + 1]}" if i + 1 < len(edges) else "+"
            out.append(f"  tau {lo:>3}{hi:<4} {int(c):>6}  "
                       f"{_bar(c / tot)}")
        if s["flushes"]:
            out.append(f"  buffer flushes: {s['flushes']}")

    # ----------------------------------------------------------- store counters
    st = s["store"]
    if any(st.values()):
        out.append("")
        out.append(h2("residual store"))
        out.append("  " + "  ".join(f"{k}={int(v)}"
                                    for k, v in st.items()))

    # ------------------------------------------------------------ time breakdown
    if s["spans"]:
        out.append("")
        out.append(h2("time breakdown (host spans)"))
        wall = sum(t for _, t in s["spans"].values()) or 1.0
        for k, (cnt, tot) in sorted(s["spans"].items(),
                                    key=lambda kv: -kv[1][1]):
            out.append(f"  {k:<12} x{cnt:<4} {tot:8.3f}s  "
                       f"{_bar(tot / wall)} {100.0 * tot / wall:5.1f}%")

    # --------------------------------------------------- cadence-gapped series
    for name, vals in s["gapped"].items():
        out.append("")
        out.append(h2(f"{name} (eval cadence; - = skipped round)"))
        shown = vals if len(vals) <= 24 else vals[-24:]
        out.append("  " + " ".join("-" if v is None else f"{v:.3f}"
                                   for v in shown))

    # ------------------------------------------------------- claims-ready rows
    out.append("")
    out.append(h2("claims-ready rows"))
    rows = [("rounds", s["n_rounds"]),
            ("uplink_wire_mb", round(s["uplink_wire"] / 1e6, 4)),
            ("downlink_wire_mb", round(s["downlink_wire"] / 1e6, 4))]
    if s["uplink_dense"] and s["uplink_wire"]:
        rows.append(("compression_x",
                     round(s["uplink_dense"] / s["uplink_wire"], 2)))
    if s["loss"]:
        rows += [("loss_first", round(s["loss"][0], 4)),
                 ("loss_last", round(s["loss"][-1], 4))]
    for k, (cnt, tot) in sorted(s["spans"].items()):
        rows.append((f"wall_s_{k}", round(tot, 3)))
    fence = "```" if md else ""
    if fence:
        out.append(fence)
    out += [f"{k},{v}" for k, v in rows]
    if fence:
        out.append(fence)
    return "\n".join(out) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="render a flight-recorder JSONL trace")
    ap.add_argument("trace", help="JSONL file written via --trace")
    ap.add_argument("--md", default="", metavar="PATH",
                    help="also write a markdown rendering here")
    args = ap.parse_args(argv)
    records = validate_file(args.trace)
    s = summarize(records)
    print(render(s, md=False), end="")
    if args.md:
        with open(args.md, "w") as fh:
            fh.write(render(s, md=True))
        print(f"wrote {args.md}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
