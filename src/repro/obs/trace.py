"""Host-side tracer: a versioned JSONL span/event sink (DESIGN.md §12).

One record per line, every record carrying ``{"v": SCHEMA_VERSION}``.  Four
record kinds make up schema v1:

  * ``meta``   — first line of every file: ``schema``, wall-clock ``ts``,
    plus whatever run metadata the writer attached (arch, topology, ...);
  * span records (``"type": "span"``) — a timed section: ``kind`` names it
    (``compile`` — a chunk whose shape compiled here, including its first
    execution, ``chunk`` — a warm chunk execution, ``eval``,
    ``checkpoint``), with ``ts`` (wall clock at entry) and ``dur_s``;
  * ``event`` records — instantaneous marks (``flush`` — an async buffer
    flush derived from the round metrics, custom marks);
  * ``stages`` / ``round`` — machine-readable telemetry: ``stages`` names
    the RoundStats byte slots once, then one ``round`` record per round
    with every metric leaf flattened to ``m`` (scalars; NaN -> null, which
    is how eval-cadence gaps serialize).

Stdlib-only at import (jax loads lazily inside the helpers that need it),
so ``repro.obs.report`` can validate and render anywhere.
"""
from __future__ import annotations

import contextlib
import json
import time

SCHEMA_VERSION = 1


def _json_scalar(x: float):
    x = float(x)
    return None if x != x else x      # NaN (cadence-skipped eval) -> null


def _path_name(entry) -> str:
    for attr in ("key", "name", "idx"):
        v = getattr(entry, attr, None)
        if v is not None:
            return str(v)
    return str(entry)


class Tracer:
    """Append-only JSONL sink.  Construct with the ``--trace`` path; every
    write flushes, so a killed run keeps its prefix."""

    def __init__(self, path: str, profile_dir: str = "", meta: dict = None):
        self.path = str(path)
        self.profile_dir = profile_dir or ""
        self._f = open(self.path, "w")
        self._write(dict(kind="meta", schema=SCHEMA_VERSION,
                         ts=time.time(), **(meta or {})))

    # ------------------------------------------------------------------ sink
    def _write(self, rec: dict) -> None:
        rec = {"v": SCHEMA_VERSION, **rec}
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()

    def event(self, kind: str, **attrs) -> None:
        self._write(dict(kind=kind, type="event", ts=time.time(), **attrs))

    @contextlib.contextmanager
    def span(self, kind: str, **attrs):
        """Timed section; yields a mutable attrs dict so the body can
        retag itself (e.g. a chunk span upgrading to ``compile`` once the
        jit cache says this shape compiled here)."""
        rec = dict(kind=kind, **attrs)
        ts, t0 = time.time(), time.perf_counter()
        try:
            yield rec
        finally:
            self._write(dict(type="span", ts=ts,
                             dur_s=time.perf_counter() - t0, **rec))

    def close(self) -> None:
        self._f.close()

    # ----------------------------------------------------------- jax helpers
    def profile(self):
        """Context manager: ``jax.profiler`` trace around the run when
        ``--profile-dir`` was given, else a no-op."""
        if not self.profile_dir:
            return contextlib.nullcontext()
        import jax
        return jax.profiler.trace(self.profile_dir)

    def emit_rounds(self, metrics, spec=None, start_round: int = 0) -> None:
        """Write the stacked ``run_rounds`` metrics as one ``round`` record
        per row.  ``spec`` (a TelemetrySpec) writes the ``stages`` naming
        record first.  Metric leaves flatten to dotted names
        (``ledger.uplink_wire``, ``round_stats.up_stage_bytes``); vector
        leaves serialize as lists, NaN as null."""
        import jax
        import numpy as np
        if metrics is None:
            return
        if spec is not None:
            self._write(dict(kind="stages", up=list(spec.up_names),
                             down=list(spec.down_names)))
        flat = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(metrics)[0]:
            flat[".".join(_path_name(p) for p in path)] = np.asarray(leaf)
        if not flat:
            return
        n = len(next(iter(flat.values())))
        for i in range(n):
            row = {}
            for k, v in flat.items():
                x = v[i]
                row[k] = (_json_scalar(x) if x.ndim == 0 else
                          [_json_scalar(y) for y in np.ravel(x)])
            self._write(dict(kind="round", round=start_round + i, m=row))


# ---------------------------------------------------------------------------
# schema validation (stdlib; used by tests and the obs-smoke CI leg)
# ---------------------------------------------------------------------------

def validate_record(rec: dict) -> None:
    """Raise ValueError when ``rec`` is not a well-formed v1 record."""
    if rec.get("v") != SCHEMA_VERSION:
        raise ValueError(f"schema version {rec.get('v')!r} != "
                         f"{SCHEMA_VERSION}")
    kind = rec.get("kind")
    if not isinstance(kind, str) or not kind:
        raise ValueError(f"record missing 'kind': {rec}")
    if kind == "meta" and rec.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"meta record schema mismatch: {rec}")
    if rec.get("type") == "span" and not isinstance(
            rec.get("dur_s"), (int, float)):
        raise ValueError(f"span record missing dur_s: {rec}")
    if kind == "round":
        if not isinstance(rec.get("m"), dict):
            raise ValueError(f"round record missing metrics dict: {rec}")
        if not isinstance(rec.get("round"), int):
            raise ValueError(f"round record missing round index: {rec}")
    if kind == "stages" and not isinstance(rec.get("up"), list):
        raise ValueError(f"stages record missing slot names: {rec}")


def validate_file(path: str) -> list:
    """Validate every line of a trace file; the first record must be the
    ``meta`` header.  Returns the parsed records."""
    records = []
    with open(path) as fh:
        for ln, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln + 1}: not JSON: {e}") from e
            validate_record(rec)
            records.append(rec)
    if not records:
        raise ValueError(f"{path}: empty trace")
    if records[0].get("kind") != "meta":
        raise ValueError(f"{path}: first record must be the meta header, "
                         f"got {records[0].get('kind')!r}")
    return records
