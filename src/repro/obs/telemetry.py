"""In-graph telemetry: the ``RoundStats`` pytree (DESIGN.md §12).

One ``RoundStats`` rides next to the ``CommLedger`` in every round's metrics
when ``FLConfig.telemetry`` is on.  Every leaf is fixed-shape f32, so the
stats stack over the donated ``lax.scan`` exactly like the ledger and
survive the eval-cadence ``lax.cond`` (``engine._gated_metrics``) as base
metrics present in both branches.

Per-stage byte attribution
--------------------------
``telemetry_spec`` decomposes a CommPipeline's static ``wire_bits`` into one
slot per carrier stage (``pipeline.stage_sequence`` — wrappers like EF / DGC
/ secagg / dpnoise bill through ``.inner`` and add no bytes of their own):
stage ``i`` bills its ``meta_bits`` over the input length it sees
(``pipeline.stage_input_lens``), and the final stage additionally bills the
``32 * carrier_len`` payload floats — together exactly the pipeline's
``wire_bits`` decomposition, summed over the model's leaves.

In-graph, ``round_stats`` multiplies the static per-stage table by the
round's unit (``n_sel`` selected clients, or 1 where the ledger already
bills absolute totals) — except the LAST slot, which is constructed as the
residual ``ledger_total - sum(previous slots)``.  That makes the slots sum
to the ledger total *bit-exactly in f32 by construction* (pure
per-slot multiplication would not: ``n * sum(t_i) != sum(n * t_i)`` in f32
once totals cross 2^24), and lets one spec serve programs whose ledger
varies across ``lax.cond`` branches (the hier cloud hop lands in the
residual slot on cloud rounds and ~0 on edge rounds).

Graph identity of the off path: every constructor here only reads values
the round program already computed (weights, ledger, staleness, store
masks) plus static python floats — nothing feeds back into params,
comm_state, or the ledger, so telemetry on/off is bit-exact in all three
(tests/test_obs.py, the differential harness).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.compress.pipeline import stage_input_lens, stage_sequence

# staleness histogram bucket edges (virtual versions): bucket i counts
# tau in [edge_{i-1}, edge_i); the last bucket is tau >= 64
STALENESS_EDGES = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0)
N_STALENESS_BUCKETS = len(STALENESS_EDGES) + 1

# epoch-scale histogram: uniform buckets over (0, 1] — bucket i counts
# scales in [i/8, (i+1)/8), scale 1.0 lands in the last bucket
N_ESCALE_BUCKETS = 8


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoundStats:
    """Fixed-shape f32 per-round telemetry (one per metrics row).

    ``up_stage_bytes`` / ``down_stage_bytes`` carry one slot per pipeline
    stage (names live OUT of the pytree, in the static ``TelemetrySpec``);
    the slots sum exactly to ``CommLedger.uplink_wire`` /
    ``downlink_wire``.  Scalars are 0 where a source doesn't exist on the
    topology (no async_state -> zero staleness histogram, no store ->
    zero counters)."""
    up_stage_bytes: jax.Array          # (S_up,)  per-stage uplink bytes
    down_stage_bytes: jax.Array        # (S_down,) per-stage downlink bytes
    staleness_hist: jax.Array          # (N_STALENESS_BUCKETS,)
    buffer_fill: jax.Array             # () async buffer occupancy at arrival
    store_hits: jax.Array              # () ResidualStore gather hits
    store_misses: jax.Array            # () gather misses
    store_evictions: jax.Array         # () occupied slots the commit evicts
    store_sketch_recovered: jax.Array  # () misses answered from the tail
    selected: jax.Array                # () clients aggregated this round
    available: jax.Array               # () cohort members available
    avail_duty: jax.Array              # () available / cohort (scenario duty)
    dropped: jax.Array                 # () mid-round scenario dropouts
    epoch_scale_hist: jax.Array        # (N_ESCALE_BUCKETS,) local-epoch scale


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Static (per-engine) stage metadata: slot names and per-unit byte
    tables.  Lives in ``RoundEngine.aux["telemetry"]``, never in the graph;
    the tables anchor the in-graph residual construction and the HLO
    cross-check (launch.hlo_analysis.name_stage_mismatch)."""
    up_names: tuple
    up_table: tuple                    # python floats, bytes per unit
    down_names: tuple
    down_table: tuple

    def up_total(self) -> float:
        return float(sum(self.up_table))

    def down_total(self) -> float:
        return float(sum(self.down_table))


def stage_byte_table(pipe, sizes, scale: float = 1.0):
    """Per-stage wire bytes for one unit (one client upload), summed over
    the model's leaf sizes.  The decomposition mirrors ``Chain.meta_bits``
    (each stage bills meta over its input length) plus the final stage's
    ``32 * carrier_len`` payload, so the table sums to
    ``scale * sum(pipe.wire_bits(n) for n in sizes) / 8`` up to float
    summation order."""
    stages = stage_sequence(pipe)
    per = [0.0] * len(stages)
    for n in sizes:
        ms = stage_input_lens(stages, n)
        for i, (s, m) in enumerate(zip(stages, ms)):
            per[i] += s.meta_bits(m)
        per[-1] += 32.0 * stages[-1].carrier_len(ms[-1])
    return tuple(scale * b / 8.0 for b in per)


def telemetry_spec(up, down, sizes, up_scale: float = 1.0,
                   down_scale: float = 1.0, extra_up=()) -> TelemetrySpec:
    """Build the static spec for an uplink/downlink pipeline pair.

    ``extra_up`` appends named absolute-byte slots after the uplink stages
    (the hier topology's cross-pod hop); the LAST up slot is the in-graph
    residual anchor, so appended slots absorb ledger terms the stage table
    doesn't cover."""
    up_stages = stage_sequence(up)
    up_names = tuple(s.name for s in up_stages)
    up_table = stage_byte_table(up, sizes, up_scale)
    for name, nbytes in extra_up:
        up_names += (name,)
        up_table += (float(nbytes),)
    if down is not None:
        down_names = tuple(s.name for s in stage_sequence(down))
        down_table = stage_byte_table(down, sizes, down_scale)
    else:
        down_names, down_table = ("none",), (0.0,)
    return TelemetrySpec(up_names, up_table, down_names, down_table)


def staleness_hist(tau, weights=None) -> jax.Array:
    """(N_STALENESS_BUCKETS,) f32 histogram of staleness values.  A scalar
    ``tau`` (one async arrival) yields a one-hot; a vector (e.g. the
    buffer's per-slot ``buf_tau``) with an occupancy-mask ``weights`` sums
    per bucket."""
    tau = jnp.atleast_1d(jnp.asarray(tau, jnp.float32))
    w = jnp.ones_like(tau) if weights is None else \
        jnp.asarray(weights, jnp.float32).reshape(tau.shape)
    edges = jnp.asarray(STALENESS_EDGES, jnp.float32)
    idx = (tau[:, None] >= edges[None, :]).sum(axis=1)
    return jnp.zeros((N_STALENESS_BUCKETS,), jnp.float32).at[idx].add(w)


def epoch_scale_hist(scale, weights=None) -> jax.Array:
    """(N_ESCALE_BUCKETS,) f32 histogram of per-client local-epoch scales
    (the scenario's heterogeneity-aware dispatch, ``scenario.epoch_steps``).
    ``weights`` masks out unselected clients."""
    scale = jnp.atleast_1d(jnp.asarray(scale, jnp.float32))
    w = jnp.ones_like(scale) if weights is None else \
        jnp.asarray(weights, jnp.float32).reshape(scale.shape)
    idx = jnp.clip(jnp.floor(scale * N_ESCALE_BUCKETS).astype(jnp.int32),
                   0, N_ESCALE_BUCKETS - 1)
    return jnp.zeros((N_ESCALE_BUCKETS,), jnp.float32).at[idx].add(w)


def _residual_slots(table, unit, total) -> jax.Array:
    """Stage slots: ``unit * table[i]`` for every slot but the last; the
    last is ``total - sum(previous)``, so the reconstruction
    ``sum(previous) + last == total`` holds bit-exactly in f32."""
    unit = jnp.asarray(unit, jnp.float32)
    parts = [unit * jnp.float32(t) for t in table[:-1]]
    partial = jnp.float32(0.0)
    for p in parts:
        partial = partial + p
    parts.append(jnp.asarray(total, jnp.float32) - partial)
    return jnp.stack(parts)


def round_stats(spec: TelemetrySpec, ledger, *, up_unit, down_unit=None,
                staleness=None, staleness_weights=None, fill=None,
                store=None, selected=None, available=None,
                avail_duty=None, dropped=None,
                epoch_scale=None, epoch_scale_weights=None) -> RoundStats:
    """Assemble one round's ``RoundStats`` from already-computed values.

    ``up_unit`` multiplies the per-unit stage table (``n_sel`` on the
    server topologies, 1.0 where the ledger is already absolute);
    ``down_unit`` defaults to ``up_unit``.  ``store`` is the dict
    ``ResidualStore.stats`` returns.  ``avail_duty`` / ``dropped`` /
    ``epoch_scale`` are the scenario counters (core.scenario, DESIGN.md
    §13).  Everything absent defaults to 0."""
    z = jnp.zeros((), jnp.float32)
    f = lambda v: z if v is None else jnp.asarray(v, jnp.float32)
    store = store or {}
    hist = (jnp.zeros((N_STALENESS_BUCKETS,), jnp.float32)
            if staleness is None
            else staleness_hist(staleness, staleness_weights))
    e_hist = (jnp.zeros((N_ESCALE_BUCKETS,), jnp.float32)
              if epoch_scale is None
              else epoch_scale_hist(epoch_scale, epoch_scale_weights))
    return RoundStats(
        up_stage_bytes=_residual_slots(spec.up_table, up_unit,
                                       ledger.uplink_wire),
        down_stage_bytes=_residual_slots(
            spec.down_table, up_unit if down_unit is None else down_unit,
            ledger.downlink_wire),
        staleness_hist=hist,
        buffer_fill=f(fill),
        store_hits=f(store.get("hits")),
        store_misses=f(store.get("misses")),
        store_evictions=f(store.get("evictions")),
        store_sketch_recovered=f(store.get("sketch_recovered")),
        selected=f(selected),
        available=f(available),
        avail_duty=f(avail_duty),
        dropped=f(dropped),
        epoch_scale_hist=e_hist,
    )


def zero_stats(spec: TelemetrySpec) -> RoundStats:
    """An all-zero RoundStats with ``spec``'s slot shapes (structure
    template for cond branches and tests)."""
    z = jnp.zeros((), jnp.float32)
    return RoundStats(
        up_stage_bytes=jnp.zeros((len(spec.up_table),), jnp.float32),
        down_stage_bytes=jnp.zeros((len(spec.down_table),), jnp.float32),
        staleness_hist=jnp.zeros((N_STALENESS_BUCKETS,), jnp.float32),
        buffer_fill=z, store_hits=z, store_misses=z, store_evictions=z,
        store_sketch_recovered=z, selected=z, available=z,
        avail_duty=z, dropped=z,
        epoch_scale_hist=jnp.zeros((N_ESCALE_BUCKETS,), jnp.float32),
    )
