"""Pallas TPU kernel: count-sketch accumulation (FetchSGD [66]).

GPU FetchSGD scatters x_i into S[j, h_j(i)] with atomics. TPUs have no fast
scatter unit — the TPU-native adaptation recasts the hash-scatter as a
**one-hot matmul on the MXU**:

    S[j, :] += (s_j ⊙ x_chunk) @ onehot(h_j(chunk))          (1, C)·(C, cols)

The hash h_j(i) = ((a_j·i + b_j) mod P) mod cols and sign s_j(i) are computed
in-kernel from ``broadcasted_iota`` over the *global* element index
(program_id·CHUNK + lane), so only x itself is read from HBM.

Grid is (rows, n/CHUNK); the output block (1, cols) for row j is revisited by
every chunk step — initialised at chunk 0, accumulated thereafter (standard
Pallas revisiting-output reduction). TPU grids run minor-most-fastest and
sequentially per core, so the accumulation is race-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 1024


def _kernel(x_ref, a_ref, b_ref, out_ref, *, cols: int):
    j = pl.program_id(0)          # sketch row
    c = pl.program_id(1)          # chunk index

    x = x_ref[...]                                   # (CHUNK,)
    idx = (jnp.uint32(c * CHUNK)
           + jax.lax.broadcasted_iota(jnp.uint32, (CHUNK,), 0))
    ab = a_ref[0] * idx + b_ref[0]                   # uint32 wraparound hash
    h = (ab % jnp.uint32(cols)).astype(jnp.int32)    # (CHUNK,)
    s = jnp.where((ab // jnp.uint32(cols)) % 2 == 0, 1.0, -1.0).astype(jnp.float32)

    onehot = (h[:, None] == jax.lax.broadcasted_iota(jnp.int32, (CHUNK, cols), 1))
    partial = jnp.dot((s * x)[None, :], onehot.astype(jnp.float32),
                      preferred_element_type=jnp.float32)      # (1, cols)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("rows", "cols", "interpret"))
def count_sketch(x, a, b, rows: int, cols: int, interpret=False):
    """x (n,) f32 with n % CHUNK == 0; a, b (rows,) int32 hash params.
    Returns S (rows, cols) f32."""
    n = x.shape[0]
    assert n % CHUNK == 0, (n, CHUNK)
    grid = (rows, n // CHUNK)
    return pl.pallas_call(
        functools.partial(_kernel, cols=cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((CHUNK,), lambda j, c: (c,)),
            pl.BlockSpec((1,), lambda j, c: (j,)),
            pl.BlockSpec((1,), lambda j, c: (j,)),
        ],
        out_specs=pl.BlockSpec((1, cols), lambda j, c: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(x, a, b)
