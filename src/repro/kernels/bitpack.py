"""Pallas TPU kernels: bit-packed wire formats, fused into the encode pass.

The staged kernels (``ternary.py``, ``qsgd.py``) emit int8 codes that a
separate pack pass would have to re-read from HBM.  These kernels fuse the
bitpack into the quantize/ternarize tile loop, so per grid step the f32 tile
is read once and only the *packed* bytes are written — the uncompressed
tensor and the unpacked codes never round-trip HBM (DESIGN.md §10):

  * ``ternarize_pack_blocked`` — threshold -> sign -> 2-bit pack + the mu
    partial sums, one pass (the fused dense-STC wire format).
  * ``qsgd_pack_blocked``      — scale -> normalise -> stochastic round ->
    nibble pack + per-row scale, one pass (``bits <= 4`` only).
  * ``pack_codes_blocked`` / ``unpack_codes_blocked`` — standalone pack and
    unpack passes over an int8 code matrix (2 or 4 bits/code), used by the
    round-trip parity tests and as the building block for future
    compress-into-collective fusions.

Byte layout matches ``repro.compress.wire_format`` exactly: little-endian
fields within each byte, byte ``j`` of a row covering codes ``4j..4j+3``
(2-bit) or ``2j..2j+1`` (4-bit).  ``block`` must be divisible by the codes
per byte, so the flattened packed rows equal the flat-vector packing of the
flattened codes — the cross-backend payload-identity the parity harness
asserts.  The strided lane slicing (``u[:, 0::4]``) interprets cleanly on
CPU; on Mosaic it lowers to lane shifts/selects (packed widths stay lane
multiples: 2048/4 = 512).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8


def _pack_lanes(u, bits):
    """uint8 fields (ROWS, block) -> packed bytes (ROWS, block*bits//8)."""
    if bits == 2:
        return (u[:, 0::4] | (u[:, 1::4] << 2) | (u[:, 2::4] << 4)
                | (u[:, 3::4] << 6))
    return u[:, 0::2] | (u[:, 1::2] << 4)


def _unpack_lanes(p, bits):
    """packed bytes (ROWS, pblock) -> int8 codes (ROWS, pblock*8//bits),
    sign-extended from the ``bits``-bit field."""
    per = 8 // bits
    rep = jnp.repeat(p, per, axis=1)
    sh = (jax.lax.broadcasted_iota(jnp.uint8, rep.shape, 1) % per) * bits
    mask, off = (3, 2) if bits == 2 else (15, 8)
    u = (rep >> sh) & mask
    return ((u + off) & mask).astype(jnp.int8) - off


def _tern_pack_kernel(x_ref, t_ref, packed_ref, psum_ref, pcnt_ref):
    x = x_ref[...]                                   # (ROWS, block) f32
    t = t_ref[0]
    mag = jnp.abs(x)
    keep = mag >= t
    code = (jnp.sign(x) * keep).astype(jnp.int8)
    packed_ref[...] = _pack_lanes((code & 3).astype(jnp.uint8), 2)
    psum_ref[...] = jnp.sum(jnp.where(keep, mag, 0.0), axis=1)
    pcnt_ref[...] = jnp.sum(keep.astype(jnp.float32), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ternarize_pack_blocked(xb, thresh, interpret=False):
    """xb (nb, block) f32, thresh () f32 -> (packed uint8 (nb, block//4),
    psum f32 (nb,), pcnt f32 (nb,)).  Pad lanes (x == 0) pack to zero bytes
    for any threshold, so slicing the flat bytes to ceil(n/4) is exact."""
    nb, block = xb.shape
    assert nb % ROWS == 0 and block % 4 == 0, (nb, block)
    grid = (nb // ROWS,)
    t = jnp.reshape(thresh.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _tern_pack_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS, block // 4), lambda i: (i, 0)),
            pl.BlockSpec((ROWS,), lambda i: (i,)),
            pl.BlockSpec((ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block // 4), jnp.uint8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(xb, t)


def _qsgd_pack_kernel(x_ref, u_ref, packed_ref, scale_ref, *, levels):
    x = x_ref[...]                                   # (ROWS, block) f32
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    y = x / jnp.maximum(scale, 1e-30) * levels
    q = jnp.floor(y + u_ref[...]).astype(jnp.int8)
    packed_ref[...] = _pack_lanes((q & 15).astype(jnp.uint8), 4)
    scale_ref[...] = scale[:, 0]


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def qsgd_pack_blocked(xb, u, bits=4, interpret=False):
    """xb, u: (nb, block) f32 -> (packed uint8 (nb, block//2), scale f32
    (nb,)).  ``bits <= 4`` so levels fit the [-8, 7] nibble losslessly."""
    nb, block = xb.shape
    assert nb % ROWS == 0 and block % 2 == 0, (nb, block)
    assert 2 <= bits <= 4, bits
    levels = 2 ** (bits - 1) - 1
    grid = (nb // ROWS,)
    return pl.pallas_call(
        functools.partial(_qsgd_pack_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS, block // 2), lambda i: (i, 0)),
            pl.BlockSpec((ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block // 2), jnp.uint8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(xb, u)


def _pack_only_kernel(c_ref, p_ref, *, bits):
    mask = (1 << bits) - 1
    p_ref[...] = _pack_lanes((c_ref[...] & mask).astype(jnp.uint8), bits)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def pack_codes_blocked(cb, bits=2, interpret=False):
    """int8 codes (nb, block) -> packed uint8 (nb, block*bits//8)."""
    nb, block = cb.shape
    per = 8 // bits
    assert nb % ROWS == 0 and block % per == 0 and bits in (2, 4)
    return pl.pallas_call(
        functools.partial(_pack_only_kernel, bits=bits),
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, block // per), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, block // per), jnp.uint8)],
        interpret=interpret,
    )(cb)[0]


def _unpack_only_kernel(p_ref, c_ref, *, bits):
    c_ref[...] = _unpack_lanes(p_ref[...], bits)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def unpack_codes_blocked(pb, bits=2, interpret=False):
    """packed uint8 (nb, pblock) -> int8 codes (nb, pblock*8//bits)."""
    nb, pblock = pb.shape
    per = 8 // bits
    assert nb % ROWS == 0 and bits in (2, 4)
    return pl.pallas_call(
        functools.partial(_unpack_only_kernel, bits=bits),
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, pblock), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, pblock * per), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, pblock * per), jnp.int8)],
        interpret=interpret,
    )(pb)[0]
