"""Pallas TPU kernel: QSGD stochastic uniform quantization.

One HBM pass fuses (per-block max-abs scale -> normalise -> stochastic round
-> int8 cast). The pure-JAX version needs two passes (reduce, then map); at
the FL hot spot (quantise every parameter leaf every round, ~10^8–10^11 bytes)
the op is HBM-bandwidth-bound, so the fusion halves its memory term.

Layout: x is pre-reshaped to (nb, block); each grid step owns ROWS rows of
the block matrix in VMEM. ``block`` must be a multiple of 128 (lane width);
ROWS=8 keeps the tile at 8×block×4 B (e.g. 64 KiB for block=2048) — well
inside VMEM. Stochastic-rounding uniforms are an *input* (generated with
jax.random outside) so the kernel is bit-reproducible against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8


def _kernel(x_ref, u_ref, q_ref, scale_ref, *, levels: int):
    x = x_ref[...]                                   # (ROWS, block) f32
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    y = x / jnp.maximum(scale, 1e-30) * levels
    q = jnp.floor(y + u_ref[...])
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale[:, 0]


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def qsgd_quantize_blocked(xb, u, bits=8, interpret=False):
    """xb, u: (nb, block) f32. Returns (q int8 (nb, block), scale f32 (nb,))."""
    nb, block = xb.shape
    assert nb % ROWS == 0, (nb, ROWS)
    levels = 2 ** (bits - 1) - 1
    grid = (nb // ROWS,)
    return pl.pallas_call(
        functools.partial(_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(xb, u)
