"""Pallas TPU kernel: STC ternarisation (threshold -> {−mu, 0, +mu} partials).

Given the global top-k magnitude threshold t (computed once outside with
``lax.top_k``), one fused HBM pass emits per-tile ternary codes plus the
partial sums needed for mu = mean(|x| over the support):

    code  = sign(x) * (|x| >= t)          int8
    psum  = Σ_tile |x| · (|x| >= t)       f32 per grid row
    pcnt  = Σ_tile (|x| >= t)             f32 per grid row

The caller finalises mu = Σpsum / Σpcnt (a tiny reduction) — so the whole STC
compress is 1 top-k + 1 fused pass instead of 3 elementwise passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8


def _kernel(x_ref, t_ref, code_ref, psum_ref, pcnt_ref):
    x = x_ref[...]                                   # (ROWS, block)
    t = t_ref[0]
    mag = jnp.abs(x)
    keep = mag >= t
    code_ref[...] = (jnp.sign(x) * keep).astype(jnp.int8)
    psum_ref[...] = jnp.sum(jnp.where(keep, mag, 0.0), axis=1)
    pcnt_ref[...] = jnp.sum(keep.astype(jnp.float32), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ternarize_blocked(xb, thresh, interpret=False):
    """xb (nb, block) f32, thresh () f32 ->
    (code int8 (nb, block), psum f32 (nb,), pcnt f32 (nb,))."""
    nb, block = xb.shape
    assert nb % ROWS == 0
    grid = (nb // ROWS,)
    t = jnp.reshape(thresh.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((ROWS,), lambda i: (i,)),
            pl.BlockSpec((ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(xb, t)
