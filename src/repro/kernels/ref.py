"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``ref_*`` takes exactly the same inputs as its kernel counterpart and
must match it to float tolerance; the test suite sweeps shapes and dtypes
asserting ``assert_allclose(kernel(...), ref(...))`` with ``interpret=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

def ref_qsgd_quantize_blocked(xb, u, bits=8):
    levels = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    y = xb / jnp.maximum(scale, 1e-30) * levels
    q = jnp.floor(y + u).astype(jnp.int8)
    return q, scale[:, 0]


def ref_ternarize_blocked(xb, thresh):
    mag = jnp.abs(xb)
    keep = mag >= thresh
    code = (jnp.sign(xb) * keep).astype(jnp.int8)
    psum = jnp.sum(jnp.where(keep, mag, 0.0), axis=1)
    pcnt = keep.sum(axis=1).astype(jnp.float32)
    return code, psum, pcnt


def ref_threshold_sparsify_blocked(xb, thresh):
    keep = jnp.abs(xb) >= thresh
    kept = jnp.where(keep, xb, 0.0)
    return kept, xb - kept


def ref_count_sketch(x, a, b, rows, cols):
    from repro.compress.sketch import bucket_and_sign
    n = x.shape[0]
    h, s = bucket_and_sign(jnp.arange(n), a, b, cols)
    sx = s * x.astype(jnp.float32)[None, :]
    return jax.vmap(lambda hv, v: jnp.zeros(cols, jnp.float32).at[hv].add(v))(h, sx)
