"""Pallas TPU kernel: fused threshold-sparsify + error-feedback residual.

Top-k sparsification with error feedback performs, per round and per leaf:
    kept  = x * (|x| >= t)        (the update that goes on the wire)
    resid = x - kept              (the error-feedback memory)
Fusing both into one HBM pass halves the memory traffic of the EF hot loop
(vs materialising `kept` then recomputing `x - kept`). Index *extraction*
(compaction to k slots) is data-dependent scatter/gather and stays in XLA
(`lax.top_k`) — TPUs have no efficient in-kernel compaction; see DESIGN.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8


def _kernel(x_ref, t_ref, kept_ref, resid_ref):
    x = x_ref[...]
    keep = jnp.abs(x) >= t_ref[0]
    kept = jnp.where(keep, x, 0.0)
    kept_ref[...] = kept
    resid_ref[...] = x - kept


@functools.partial(jax.jit, static_argnames=("interpret",))
def threshold_sparsify_blocked(xb, thresh, interpret=False):
    """xb (nb, block) f32 -> (kept, resid) same shape."""
    nb, block = xb.shape
    assert nb % ROWS == 0
    t = jnp.reshape(thresh.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _kernel,
        grid=(nb // ROWS,),
        in_specs=[
            pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.float32),
            jax.ShapeDtypeStruct((nb, block), jnp.float32),
        ],
        interpret=interpret,
    )(xb, t)
