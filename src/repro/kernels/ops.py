"""Public jit'd wrappers over the Pallas compression kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs through JAX's interpreter, proving the Pallas logic without
TPU hardware. On a real TPU backend the same calls lower to Mosaic.

Each wrapper handles the flat-vector <-> blocked layout plumbing so callers
(the compressors in ``repro.compress``) see the same flat-f32 interface as
the pure-JAX paths.  Layout contract (DESIGN.md §6): the kernel grid pads
the row count up to a multiple of ``ROWS``, but every wrapper slices its
outputs back to the *logical* payload — ``ceil(n / block)`` rows — before
returning, so pad lanes never reach the wire or the ledger.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bitpack as _bp
from repro.kernels import count_sketch as _cs
from repro.kernels import qsgd as _qsgd
from repro.kernels import ternary as _tern
from repro.kernels import topk_mask as _topk

ROWS = _qsgd.ROWS


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_blocked(x, block):
    n = x.shape[0]
    nb = -(-n // block)
    nb = -(-nb // ROWS) * ROWS          # grid rows multiple of ROWS
    pad = nb * block - n
    return jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(nb, block), pad


def _logical_rows(n, block):
    """Rows of the wire payload: pad rows beyond these carry no bytes."""
    return -(-n // block)


def qsgd_quantize(x, u, bits=8, block=2048):
    """Flat f32 (n,) + uniforms (n,) -> (q int8 (nb,block), scale f32 (nb,))
    with nb = ceil(n/block) — grid pad rows are sliced off."""
    n = x.shape[0]
    xb, pad = _to_blocked(x, block)
    ub, _ = _to_blocked(u, block)
    q, scale = _qsgd.qsgd_quantize_blocked(xb, ub, bits=bits,
                                           interpret=_interpret())
    nb = _logical_rows(n, block)
    return q[:nb], scale[:nb]


def qsgd_quantize_packed(x, u, bits=4, block=2048):
    """Fused quantize + nibble pack (``bits <= 4``): flat f32 (n,) +
    uniforms (n,) -> (packed uint8 (ceil(n/2),), scale f32 (nb,)) with
    nb = ceil(n/block).  The packed bytes equal ``wire_format.pack4`` of
    the staged kernel's flat codes bit-exactly; an odd short-carrier block
    (a chain carrier of odd k < block) cannot nibble-pack in-kernel, so it
    quantizes fused and packs in XLA (which fuses the shift/or anyway)."""
    n = x.shape[0]
    xb, pad = _to_blocked(x, block)
    ub, _ = _to_blocked(u, block)
    nb = _logical_rows(n, block)
    nbytes = -(-n // 2)
    if xb.shape[1] % 2:
        from repro.compress.wire_format import pack4
        q, scale = _qsgd.qsgd_quantize_blocked(xb, ub, bits=bits,
                                               interpret=_interpret())
        return pack4(q[:nb].reshape(-1)[:n]), scale[:nb]
    packed, scale = _bp.qsgd_pack_blocked(xb, ub, bits=bits,
                                          interpret=_interpret())
    return packed.reshape(-1)[:nbytes], scale[:nb]


def _k_from_fraction(n, fraction):
    """Static-shape-safe top-k count: ``fraction`` may be a traced scalar
    (e.g. the DGC warm-up's annealed fraction) — the same construction as
    ``MomentumCorrection._anneal_mask``."""
    frac = jnp.asarray(fraction, jnp.float32)
    return jnp.clip(jnp.round(n * frac).astype(jnp.int32), 1, n)


def _stc_threshold(x, fraction, max_fraction=None):
    """Top-k magnitude threshold for a static OR traced ``fraction``.

    Traced fractions (the DGC warm-up's per-round anneal) used to pay a
    full ``jnp.sort`` here; instead, one ``lax.top_k`` at the schedule's
    *static* widest k (``max_fraction``, e.g. ``final**(1/(W+1))`` — the
    round-0 fraction bounds every later round's) yields a descending prefix
    the traced order statistic is gathered from.  ``max_fraction=None``
    falls back to a full-length top_k (bit-identical to the sort).

    Perf trap: the order statistic must be read with a *reduction*
    (``jnp.min`` over the prefix), never a scalar slice or dynamic gather
    — a slice/gather fused into top_k's output defeats XLA's TopkRewriter
    pattern (sort+slice -> fast partial-select custom call) and silently
    reverts to a full variadic sort, ~4.5x slower on CPU at k = 0.1 n.
    The min over the descending prefix is the prefix's last element
    bit-exactly, and it vmaps (the engine's per-client wire vmap)."""
    n = x.shape[0]
    if isinstance(fraction, (int, float)):
        k = max(1, min(int(round(n * fraction)), n))
        return jnp.min(jax.lax.top_k(jnp.abs(x), k)[0])
    k = _k_from_fraction(n, fraction)
    kmax = (n if max_fraction is None
            else max(1, min(int(round(n * max_fraction)), n)))
    prefix = jax.lax.top_k(jnp.abs(x), kmax)[0]
    return jnp.min(jnp.where(jnp.arange(kmax) < jnp.minimum(k, kmax),
                             prefix, jnp.inf))


def stc_ternarize(x, fraction=0.01, block=2048, max_fraction=None):
    """Full STC compress: top-k threshold + fused ternarise pass.
    Returns (code int8 flat (n,), mu f32 scalar).  ``fraction`` may be a
    traced value (composes with ``dgc_warmup_rounds`` annealing); pass the
    schedule's static ``max_fraction`` so the threshold costs one
    ``lax.top_k`` over the widest-round prefix instead of a full sort."""
    n = x.shape[0]
    thresh = _stc_threshold(x, fraction, max_fraction)
    xb, pad = _to_blocked(x, block)
    code, psum, pcnt = _tern.ternarize_blocked(xb, thresh,
                                               interpret=_interpret())
    mu = psum.sum() / jnp.maximum(pcnt.sum(), 1.0)
    return code.reshape(-1)[:n], mu


def stc_ternarize_packed(x, fraction=0.01, block=2048, max_fraction=None):
    """Fused dense-STC wire format: top-k threshold + ONE ternarise+2-bit-pack
    pass (``repro.kernels.bitpack``).  Returns (packed uint8 flat
    (ceil(n/4),), mu f32 scalar) — the packed codes are exactly
    ``wire_format.pack2`` of ``stc_ternarize``'s codes, but the int8 code
    tensor never round-trips HBM."""
    n = x.shape[0]
    thresh = _stc_threshold(x, fraction, max_fraction)
    xb, pad = _to_blocked(x, block)
    packed, psum, pcnt = _bp.ternarize_pack_blocked(xb, thresh,
                                                    interpret=_interpret())
    mu = psum.sum() / jnp.maximum(pcnt.sum(), 1.0)
    return packed.reshape(-1)[:-(-n // 4)], mu


def ternarize_signs(x, block=2048):
    """The chainable Ternary stage's fused pass: full-support ternarise
    (threshold 0 keeps everything; flat pads are sign(0) = 0) returning
    (sign int8 flat (n,), sum|x| f32 scalar).  The caller finalises
    mu = sum|x| / n over the *logical* length, so pad lanes never enter
    the mean."""
    n = x.shape[0]
    xb, pad = _to_blocked(x, block)
    code, psum, _ = _tern.ternarize_blocked(xb, jnp.float32(0.0),
                                            interpret=_interpret())
    return code.reshape(-1)[:n], psum.sum()


def ternarize_signs_packed(x, block=2048):
    """Ternary's packed wire format in one fused pass: full-support
    ternarise + 2-bit pack.  Returns (packed uint8 flat (ceil(n/4),),
    sum|x| f32 scalar).  Pad lanes are sign(0) = 0 -> zero bits, so the
    flat byte slice is bit-identical to ``wire_format.pack2`` of the
    unpacked signs."""
    n = x.shape[0]
    xb, pad = _to_blocked(x, block)
    packed, psum, _ = _bp.ternarize_pack_blocked(xb, jnp.float32(0.0),
                                                 interpret=_interpret())
    return packed.reshape(-1)[:-(-n // 4)], psum.sum()


def threshold_sparsify(x, thresh, block=2048):
    """Fused (kept, error-feedback residual) in one pass. Flat f32 in/out."""
    n = x.shape[0]
    xb, pad = _to_blocked(x, block)
    kept, resid = _topk.threshold_sparsify_blocked(xb, thresh,
                                                   interpret=_interpret())
    return kept.reshape(-1)[:n], resid.reshape(-1)[:n]


def sketch(x, rows=5, cols=4096, seed=17):
    """Count-sketch via the one-hot-MXU kernel. Flat f32 (n,) -> (rows, cols)."""
    from repro.compress.sketch import hash_params
    n = x.shape[0]
    pad = (-n) % _cs.CHUNK
    xp = jnp.pad(x.astype(jnp.float32), (0, pad))
    a, b = hash_params(rows, seed)
    S = _cs.count_sketch(xp, a, b, rows, cols, interpret=_interpret())
    # padded elements are zero-valued, so their bucket contributions are
    # zero and S is already exact.
    return S
