"""Public jit'd wrappers over the Pallas compression kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs through JAX's interpreter, proving the Pallas logic without
TPU hardware. On a real TPU backend the same calls lower to Mosaic.

Each wrapper handles the flat-vector <-> blocked layout plumbing so callers
(the compressors in ``repro.compress``) see the same flat-f32 interface as
the pure-JAX paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import count_sketch as _cs
from repro.kernels import qsgd as _qsgd
from repro.kernels import ternary as _tern
from repro.kernels import topk_mask as _topk

ROWS = _qsgd.ROWS


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_blocked(x, block):
    n = x.shape[0]
    nb = -(-n // block)
    nb = -(-nb // ROWS) * ROWS          # grid rows multiple of ROWS
    pad = nb * block - n
    return jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(nb, block), pad


def qsgd_quantize(x, u, bits=8, block=2048):
    """Flat f32 (n,) + uniforms (n,) -> (q int8 (nb,block), scale f32 (nb,))."""
    xb, pad = _to_blocked(x, block)
    ub, _ = _to_blocked(u, block)
    q, scale = _qsgd.qsgd_quantize_blocked(xb, ub, bits=bits,
                                           interpret=_interpret())
    return q, scale


def stc_ternarize(x, fraction=0.01, block=2048):
    """Full STC compress: top-k threshold + fused ternarise pass.
    Returns (code int8 flat (n,), mu f32 scalar)."""
    n = x.shape[0]
    k = max(1, int(round(n * fraction)))
    thresh = jax.lax.top_k(jnp.abs(x), k)[0][-1]
    xb, pad = _to_blocked(x, block)
    code, psum, pcnt = _tern.ternarize_blocked(xb, thresh,
                                               interpret=_interpret())
    mu = psum.sum() / jnp.maximum(pcnt.sum(), 1.0)
    return code.reshape(-1)[:n], mu


def threshold_sparsify(x, thresh, block=2048):
    """Fused (kept, error-feedback residual) in one pass. Flat f32 in/out."""
    n = x.shape[0]
    xb, pad = _to_blocked(x, block)
    kept, resid = _topk.threshold_sparsify_blocked(xb, thresh,
                                                   interpret=_interpret())
    return kept.reshape(-1)[:n], resid.reshape(-1)[:n]


def sketch(x, rows=5, cols=4096, seed=17):
    """Count-sketch via the one-hot-MXU kernel. Flat f32 (n,) -> (rows, cols)."""
    from repro.compress.sketch import hash_params
    n = x.shape[0]
    pad = (-n) % _cs.CHUNK
    xp = jnp.pad(x.astype(jnp.float32), (0, pad))
    a, b = hash_params(rows, seed)
    S = _cs.count_sketch(xp, a, b, rows, cols, interpret=_interpret())
    if pad:
        # remove the padded elements' (zero-valued) contributions: zeros add
        # nothing, so S is already exact.
        pass
    return S
