"""Flat-npz pytree checkpointing (server global model + FL state).

Key encoding: pytree paths joined with '/'. Works for any pytree of arrays;
restores onto the caller-provided target structure (and shardings, if given).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str, target: PyTree, shardings: PyTree | None = None) -> PyTree:
    with np.load(path) as data:
        flat = dict(data)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for p, leaf in leaves_p:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in p)
        arr = flat[key]
        assert arr.shape == tuple(np.shape(leaf)), (key, arr.shape, np.shape(leaf))
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree
