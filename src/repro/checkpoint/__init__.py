from repro.checkpoint.checkpoint import save, restore
