"""Layer primitives shared by every assigned architecture.

Conventions:
  x       : (B, S, D)   activations
  q       : (B, S, H, hd)
  k, v    : (B, S, KV, hd)        GQA group size G = H // KV
  caches  : dict per block; attention: {"k","v"} (+ ring-buffer "slot_pos"),
            mamba: {"state","conv"}; cross-attn: {"ek","ev"}.

Parameters are declared via :class:`ParamDef` (shape, logical axes, init) so
that sharding rules (``sharding.py``) and initialisation derive from one
source of truth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig


# ---------------------------------------------------------------------------
# Parameter declaration / init
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    logical: tuple
    init: str = "normal"      # normal | zeros | ones | small | alog
    scale: float = 0.02


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, rng, dtype):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    rngs = jax.random.split(rng, len(leaves))

    def one(d: ParamDef, r):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        if d.init == "alog":   # mamba A_log: log of Uniform[1,16]
            u = jax.random.uniform(r, d.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dtype)
        scale = d.scale if d.init == "normal" else d.scale * 0.1
        return (jax.random.normal(r, d.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree.unflatten(treedef, [one(d, r) for d, r in zip(leaves, rngs)])


def logical_tree(defs):
    return jax.tree.map(lambda d: d.logical, defs, is_leaf=is_def)


# ---------------------------------------------------------------------------
# Norms / rope
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-5):
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * inv).astype(x.dtype) * w


def rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (S,) or broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs          # (..., S, half)
    ang = ang[..., None, :]                                         # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attn_defs(cfg: ArchConfig, cross: bool = False):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    d = {
        "wq": ParamDef((D, H * hd), ("embed", "heads")),
        "wk": ParamDef((D, KV * hd), ("embed", "kv_heads")),
        "wv": ParamDef((D, KV * hd), ("embed", "kv_heads")),
        "wo": ParamDef((H * hd, D), ("heads", "embed")),
        "ln": ParamDef((D,), ("norm",), "ones"),
    }
    if cfg.qkv_bias and not cross:
        d["bq"] = ParamDef((H * hd,), ("heads",), "zeros")
        d["bk"] = ParamDef((KV * hd,), ("kv_heads",), "zeros")
        d["bv"] = ParamDef((KV * hd,), ("kv_heads",), "zeros")
    return d


def _qkv(p, x, cfg: ArchConfig, positions, use_rope=True):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q * (hd ** -0.5), k, v


def chunked_attention(q, k, v, *, q_positions, k_positions, causal=True,
                      window=0, chunk=512, chunk_q=512):
    """Flash-style online-softmax attention, doubly tiled: an outer scan over
    query chunks, an inner scan over KV chunks. Peak score temp is
    (B, KV, G, chunk_q, chunk) — bounded regardless of sequence length, which
    is what lets the 32k-prefill and 500k-window shapes lower within VMEM/HBM
    budgets. Handles GQA grouping, causal masks and sliding windows.
    Accumulators are f32; output is cast back to q.dtype per query chunk.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    chunk_q = min(chunk_q, Sq)
    if Sk % chunk:                      # pad KV to a chunk multiple; padded
        pad = chunk - Sk % chunk        # slots get position -1 => masked out
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad), constant_values=-1)
        Sk += pad
    qpad = (-Sq) % chunk_q
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, qpad), constant_values=-1)
    nq = (Sq + qpad) // chunk_q
    nk = Sk // chunk
    qg = q.reshape(B, nq, chunk_q, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    qp = q_positions.reshape(nq, chunk_q)

    def q_body(_, qin):
        qc, qpos = qin                                # (B,KV,G,cq,hd), (cq,)

        def kv_body(carry, i):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(k_positions, i * chunk, chunk,
                                              axis=0)
            s = jnp.einsum("bkgqd,bckd->bkgqc", qc, kc,
                           preferred_element_type=jnp.float32)
            mask = jnp.broadcast_to(kp[None, :] >= 0, (chunk_q, chunk))
            if causal:
                mask &= kp[None, :] <= qpos[:, None]
            if window:
                mask &= kp[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p, vc.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, chunk_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, chunk_q), jnp.float32)
        a0 = jnp.zeros((B, KV, G, chunk_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)              # (B,KV,G,cq,hd)

    _, outs = jax.lax.scan(q_body, None, (qg, qp))    # (nq,B,KV,G,cq,hd)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq + qpad, H * hd)
    return out[:, :Sq].astype(q.dtype)


def attention_block(p, x, cfg: ArchConfig, positions, *, window=0, causal=True,
                    chunk=512, return_kv=False):
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, positions)
    out = chunked_attention(q, k, v, q_positions=positions, k_positions=positions,
                            causal=causal, window=window, chunk=chunk)
    out = out @ p["wo"]
    if return_kv:
        return x + out, (k, v)
    return x + out


# --- decode (single token, KV cache; optionally a ring buffer) --------------

def attn_cache_defs(cfg: ArchConfig, batch, cache_len, quantized=False):
    """quantized=True stores the KV cache as int8 with per-(token, head)
    scales — the paper's quantization idea applied to the *serving* memory
    wall (decode is HBM-bound on reading the cache; int8 halves that term
    vs bf16). See EXPERIMENTS.md §Perf B2."""
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    d = {
        "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
    }
    if quantized:
        d["k"] = jnp.zeros((batch, cache_len, KV, hd), jnp.int8)
        d["v"] = jnp.zeros((batch, cache_len, KV, hd), jnp.int8)
        d["kscale"] = jnp.zeros((batch, cache_len, KV, 1), jnp.float32)
        d["vscale"] = jnp.zeros((batch, cache_len, KV, 1), jnp.float32)
    else:
        d["k"] = jnp.zeros((batch, cache_len, KV, hd), cfg.dtype)
        d["v"] = jnp.zeros((batch, cache_len, KV, hd), cfg.dtype)
    return d


def _quantize_kv(x):
    """x (B,1,KV,hd) -> (int8, scale (B,1,KV,1))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(scale, 1e-30) * 127.0)
    return q.astype(jnp.int8), scale


def attention_decode(p, x, cfg: ArchConfig, cache, pos, *, window=0,
                     use_rope=True):
    """x: (B, 1, D); pos: () int32 — aligned batched decode."""
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, pos[None].astype(jnp.int32), use_rope=use_rope)
    W = cache["k"].shape[1]
    slot = jnp.mod(pos, W)
    quantized = "kscale" in cache
    new_cache = {}
    if quantized:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=1)
        cks = jax.lax.dynamic_update_slice_in_dim(cache["kscale"], ks, slot,
                                                  axis=1)
        cvs = jax.lax.dynamic_update_slice_in_dim(cache["vscale"], vs, slot,
                                                  axis=1)
        kd = ck.astype(jnp.float32) * (cks / 127.0)
        vd = cv.astype(jnp.float32) * (cvs / 127.0)
        new_cache.update(kscale=cks, vscale=cvs)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        kd, vd = ck, cv
    spos = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], pos[None].astype(jnp.int32), slot, axis=0)
    qg = q.reshape(B, 1, KV, H // KV, hd)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kd,
                   preferred_element_type=jnp.float32)
    valid = (spos >= 0) & (spos <= pos)
    if window:
        valid &= spos > pos - window
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bkgqd", w, vd.astype(jnp.float32))
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H * hd).astype(x.dtype)
    new_cache.update(k=ck, v=cv, slot_pos=spos)
    return x + out @ p["wo"], new_cache


# --- cross attention (whisper decoder) ---------------------------------------

def cross_attn_defs(cfg: ArchConfig):
    return attn_defs(cfg, cross=True)


def cross_attention(p, x, enc_kv, cfg: ArchConfig):
    """enc_kv: precomputed (ek, ev) each (B, T, KV, hd)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, H, hd) * (hd ** -0.5)
    ek, ev = enc_kv
    qg = q.reshape(B, S, KV, H // KV, hd)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg, ek, preferred_element_type=jnp.float32)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bkgqd", w, ev.astype(jnp.float32))
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H * hd).astype(x.dtype)
    return x + out @ p["wo"]


def encode_cross_kv(p, enc_out, cfg: ArchConfig):
    B, T, _ = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    ek = (enc_out @ p["wk"]).reshape(B, T, KV, hd)
    ev = (enc_out @ p["wv"]).reshape(B, T, KV, hd)
    return ek, ev


# ---------------------------------------------------------------------------
# FFN: gated (llama/qwen) and plain (whisper)
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ArchConfig, gated=True):
    D, F = cfg.d_model, cfg.d_ff
    d = {"ln": ParamDef((D,), ("norm",), "ones"),
         "w_up": ParamDef((D, F), ("embed", "ffn")),
         "w_down": ParamDef((F, D), ("ffn", "embed"))}
    if gated:
        d["w_gate"] = ParamDef((D, F), ("embed", "ffn"))
    return d


def mlp_block(p, x, cfg: ArchConfig):
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    up = h @ p["w_up"]
    if "w_gate" in p:
        up = jax.nn.silu(h @ p["w_gate"]) * up
    else:
        up = jax.nn.gelu(up)
    return x + up @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE (capacity-based dispatch; experts sharded over the model axis)
# ---------------------------------------------------------------------------

def moe_defs(cfg: ArchConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "ln": ParamDef((D,), ("norm",), "ones"),
        "router": ParamDef((D, E), ("embed", None)),
        "w_gate": ParamDef((E, D, F), ("experts", "embed", "ffn")),
        "w_up": ParamDef((E, D, F), ("experts", "embed", "ffn")),
        "w_down": ParamDef((E, F, D), ("experts", "ffn", "embed")),
    }


def moe_block(p, x, cfg: ArchConfig):
    """Top-k routing with per-expert capacity; returns (y, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    logits = (h @ p["router"]).astype(jnp.float32)              # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)               # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(cfg.expert_capacity_factor * S * K / E))
    sel = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)        # (B,S,K,E)
    combine = (sel * gate_vals[..., None]).sum(2)               # (B,S,E)
    # position of each token within its expert queue
    pos_in_e = jnp.cumsum(sel.sum(2), axis=1) - sel.sum(2)      # (B,S,E)
    keep = pos_in_e < cap
    combine = combine * keep
    disp = jax.nn.one_hot(pos_in_e.astype(jnp.int32), cap, dtype=x.dtype) \
        * (combine > 0)[..., None].astype(x.dtype)              # (B,S,E,C)

    xe = jnp.einsum("bsec,bsd->becd", disp, h)                  # (B,E,C,D)
    a = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(a) * u, p["w_down"])
    out = jnp.einsum("bsec,becd->bsd", disp * combine[..., None].astype(x.dtype), y)

    # load-balance aux loss (Switch-style)
    frac_tokens = (sel.sum(2) > 0).astype(jnp.float32).mean((0, 1))   # (E,)
    frac_prob = probs.mean((0, 1))
    aux = E * jnp.sum(frac_tokens * frac_prob)
    return x + out, aux


def block_aux_zero():
    return jnp.zeros((), jnp.float32)
