"""Model facade: one scan-over-layers decoder (optionally + encoder) that
expresses all 10 assigned architectures via ``ArchConfig.block_pattern``.

Pattern entries are ``"<mixer>[+cross][+<ffn>]"`` with mixer in
{``attn``, ``mamba``} and ffn in {``mlp``, ``moe``}, e.g.:

  dense llama/qwen  : ("attn+mlp",)
  MoE               : ("attn+moe",)
  Mamba-2           : ("mamba",)
  Jamba             : ("mamba+mlp","mamba+moe","mamba+mlp","attn+moe",
                       "mamba+mlp","mamba+moe","mamba+mlp","mamba+moe")
  Whisper decoder   : ("attn+cross+mlp",)

The layer stack is ``lax.scan`` over ``num_superblocks`` stacked parameter
trees (one superblock = one repetition of the pattern), with optional
``jax.checkpoint`` remat — this keeps the 95-layer full-size configs' HLO
compact enough to compile quickly on the dry-run host.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.models import layers as L
from repro.models import ssm as M
from repro.models.layers import ParamDef

PyTree = Any

# ---------------------------------------------------------------------------
# Activation (sequence-parallel) sharding
#
# Within one FL client the model is tensor-parallel over the ``model`` axis;
# without further constraints the residual stream (B, S, D) would replicate
# across the client's TP group — 40+ GB/chip for the 32B+ configs. Constraining
# the *sequence* dim to the model axis at superblock boundaries (Megatron-style
# sequence parallelism; GSPMD inserts the all-gather/reduce-scatter pair)
# bounds saved activations at S/|model| per chip. Batch dims stay
# UNCONSTRAINED so pod-client configs keep their data-axis batch sharding.
# ---------------------------------------------------------------------------

_ACT_MESH = None


def set_activation_mesh(mesh):
    """Launcher hook: enable sequence-parallel activation constraints."""
    global _ACT_MESH
    _ACT_MESH = mesh


def _constrain_seq(x):
    if _ACT_MESH is None or "model" not in _ACT_MESH.axis_names or x.ndim != 3:
        return x
    m = dict(_ACT_MESH.shape)["model"]
    if x.shape[1] < m or x.shape[1] % m:
        return x
    U = jax.sharding.PartitionSpec.UNCONSTRAINED
    spec = jax.sharding.PartitionSpec(U, "model", U)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_ACT_MESH, spec))


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def _block_defs(cfg: ArchConfig, entry: str):
    parts = entry.split("+")
    d: dict = {}
    if parts[0] == "attn":
        d["mixer"] = L.attn_defs(cfg)
    elif parts[0] == "mamba":
        d["mixer"] = M.mamba_defs(cfg)
    else:
        raise ValueError(entry)
    if "cross" in parts:
        d["cross"] = L.cross_attn_defs(cfg)
    if "moe" in parts:
        d["ffn"] = L.moe_defs(cfg)
    elif "mlp" in parts:
        d["ffn"] = L.mlp_defs(cfg, gated=cfg.family != "encdec")
    return d


def _stack_defs(defs, n):
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("stack",) + d.logical, d.init, d.scale),
        defs, is_leaf=L.is_def)


def param_defs(cfg: ArchConfig) -> PyTree:
    D, V = cfg.d_model, cfg.vocab_size
    sb = {f"b{i}": _block_defs(cfg, e) for i, e in enumerate(cfg.block_pattern)}
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed")),
        "final_ln": ParamDef((D,), ("norm",), "ones"),
        "layers": _stack_defs(sb, cfg.num_superblocks),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((D, V), ("embed", "vocab"))
    if cfg.encoder_layers:
        enc_block = {"mixer": L.attn_defs(cfg),
                     "ffn": L.mlp_defs(cfg, gated=False)}
        defs["encoder"] = {
            "layers": _stack_defs(enc_block, cfg.encoder_layers),
            "final_ln": ParamDef((D,), ("norm",), "ones"),
        }
    if cfg.num_patches:
        # lightweight projector for the (stubbed) vision embeddings
        defs["patch_proj"] = ParamDef((D, D), ("embed", "embed"))
    return defs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _sinusoid(S, D):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / D))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _superblock(psb, x, cfg: ArchConfig, positions, enc_out, *, causal=True,
                window=0, chunk=512):
    use_rope = cfg.family != "encdec"
    aux = jnp.zeros((), jnp.float32)
    for i, entry in enumerate(cfg.block_pattern):
        parts = entry.split("+")
        p = psb[f"b{i}"]
        if parts[0] == "attn":
            h = L.rmsnorm(x, p["mixer"]["ln"], cfg.norm_eps)
            q, k, v = L._qkv(p["mixer"], h, cfg, positions, use_rope=use_rope)
            o = L.chunked_attention(q, k, v, q_positions=positions,
                                    k_positions=positions, causal=causal,
                                    window=window, chunk=chunk)
            x = x + o @ p["mixer"]["wo"]
        else:
            x = M.mamba_block(p["mixer"], x, cfg)
        if "cross" in parts:
            ekv = L.encode_cross_kv(p["cross"], enc_out, cfg)
            x = L.cross_attention(p["cross"], x, ekv, cfg)
        if "ffn" in p:
            if "router" in p["ffn"]:
                x, a = L.moe_block(p["ffn"], x, cfg)
                aux = aux + a
            else:
                x = L.mlp_block(p["ffn"], x, cfg)
    return x, aux


def _run_stack(params_layers, x, cfg, positions, enc_out, *, causal=True,
               window=0, chunk=512):
    def body(carry, psb):
        carry = _constrain_seq(carry)
        y, aux = _superblock(psb, carry, cfg, positions, enc_out,
                             causal=causal, window=window, chunk=chunk)
        return y, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, params_layers)
    return x, auxs.sum()


def _encode(params, frontend, cfg: ArchConfig):
    """Whisper-style encoder over stubbed frame embeddings (B, T, D)."""
    T, D = frontend.shape[1], cfg.d_model
    x = frontend + _sinusoid(T, D).astype(frontend.dtype)
    pos = jnp.arange(T)
    enc = params["encoder"]

    def body(carry, psb):
        h = L.rmsnorm(carry, psb["mixer"]["ln"], cfg.norm_eps)
        q, k, v = L._qkv(psb["mixer"], h, cfg, pos, use_rope=False)
        o = L.chunked_attention(q, k, v, q_positions=pos, k_positions=pos,
                                causal=False, chunk=512)
        h2 = carry + o @ psb["mixer"]["wo"]
        h2 = L.mlp_block(psb["ffn"], h2, cfg)
        return h2, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, enc["layers"])
    return L.rmsnorm(x, enc["final_ln"], cfg.norm_eps)


def _inputs_to_x(params, batch, cfg: ArchConfig):
    """Embed tokens, handling modality prefixes. Returns (x, positions,
    enc_out, text_offset)."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    enc_out = None
    offset = 0
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)
        offset = patches.shape[1]
    if cfg.family == "encdec":
        enc_out = _encode(params, batch["frontend"].astype(x.dtype), cfg)
        x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)
    positions = jnp.arange(x.shape[1])
    return x, positions, enc_out, offset


def forward(params, batch, cfg: ArchConfig, *, window=None, chunk=512):
    """Full-sequence forward -> final hidden states (B, S_text, D)."""
    x, positions, enc_out, offset = _inputs_to_x(params, batch, cfg)
    w = cfg.sliding_window if window is None else window
    x, aux = _run_stack(params["layers"], x, cfg, positions, enc_out,
                        causal=True, window=w, chunk=chunk)
    x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    if offset:
        x = x[:, offset:]
    return x, aux


def unembed(params, x, cfg: ArchConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ w


def chunked_xent(x, w, labels, mask, chunk=512):
    """Cross-entropy without materialising (B, S, V): scan + remat over
    sequence chunks. Returns (sum_loss, sum_mask)."""
    B, S, D = x.shape
    c = min(chunk, S)
    if S % c:                       # pad to a chunk multiple; padded tokens
        pad = c - S % c             # carry mask 0 and contribute nothing
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        S += pad
    n = S // c
    xs = x.reshape(B, n, c, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, c).transpose(1, 0, 2)
    ms = mask.reshape(B, n, c).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, inp):
        xc, lc, mc = inp
        logits = (xc @ w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        tot, cnt = carry
        return (tot + ((lse - gold) * mc).sum(), cnt + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls, ms))
    return tot, cnt


def loss_fn(params, batch, cfg: ArchConfig, *, chunk=512):
    """Next-token LM loss. batch: tokens (B,S), labels (B,S), mask (B,S)
    [+ patches / frontend for vlm / encdec]."""
    x, aux = forward(params, batch, cfg, chunk=chunk)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    tot, cnt = chunked_xent(x, w, batch["labels"], batch["mask"].astype(jnp.float32),
                            chunk=chunk)
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + cfg.router_aux_weight * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, cache_len: int, enc_len: int = 0,
               quantized: bool = False):
    """Zero cache pytree; leaves stacked over superblocks (leading nsb dim).
    ``quantized`` stores attention KV as int8 + per-(token, head) scales."""
    def one_block(entry):
        parts = entry.split("+")
        d: dict = {}
        if parts[0] == "attn":
            d["kv"] = L.attn_cache_defs(cfg, batch, cache_len,
                                        quantized=quantized)
        else:
            d["kv"] = M.mamba_cache_defs(cfg, batch)
        if "cross" in parts:
            KV, hd = cfg.num_kv_heads, cfg.head_dim
            d["enc"] = {"ek": jnp.zeros((batch, enc_len, KV, hd), cfg.dtype),
                        "ev": jnp.zeros((batch, enc_len, KV, hd), cfg.dtype)}
        return d

    sb = {f"b{i}": one_block(e) for i, e in enumerate(cfg.block_pattern)}
    n = cfg.num_superblocks
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), sb)


def decode_step(params, cache, token, pos, cfg: ArchConfig, *, window=0):
    """One decode step. token (B,1) int32, pos () int32. Returns
    (logits (B,1,V), new_cache)."""
    x = jnp.take(params["embed"], token, axis=0)
    if cfg.family == "encdec":
        x = x + _sinusoid_at(pos, cfg.d_model).astype(x.dtype)

    def body(x, scanned):
        psb, csb = scanned
        new_csb = {}
        for i, entry in enumerate(cfg.block_pattern):
            parts = entry.split("+")
            p, c = psb[f"b{i}"], csb[f"b{i}"]
            nc = {}
            if parts[0] == "attn":
                x, nc["kv"] = L.attention_decode(
                    p["mixer"], x, cfg, c["kv"], pos, window=window,
                    use_rope=cfg.family != "encdec")
            else:
                x, nc["kv"] = M.mamba_decode(p["mixer"], x, cfg, c["kv"])
            if "cross" in parts:
                x = L.cross_attention(p["cross"], x,
                                      (c["enc"]["ek"], c["enc"]["ev"]), cfg)
                nc["enc"] = c["enc"]
            if "ffn" in p:
                if "router" in p["ffn"]:
                    x, _ = L.moe_block(p["ffn"], x, cfg)
                else:
                    x = L.mlp_block(p["ffn"], x, cfg)
            new_csb[f"b{i}"] = nc
        return x, new_csb

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = unembed(params, x, cfg)
    return logits, new_cache


def _sinusoid_at(pos, D):
    dim = jnp.arange(D // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10_000.0 ** (2 * dim / D))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]


def prefill(params, batch, cfg: ArchConfig, *, window=0, chunk=512):
    """Full-sequence prefill returning last-position logits (the KV caches for
    the dry-run's decode shapes enter via ``init_cache`` ShapeDtypeStructs, so
    prefill here only needs to prove the full-context forward lowers)."""
    x, aux = forward(params, batch, cfg, window=window, chunk=chunk)
    logits = unembed(params, x[:, -1:], cfg)
    return logits


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.defs = param_defs(cfg)

    def init(self, rng) -> PyTree:
        return L.init_params(self.defs, rng, self.cfg.dtype)

    def logical_axes(self) -> PyTree:
        return L.logical_tree(self.defs)

    def abstract_params(self) -> PyTree:
        return jax.tree.map(
            lambda d: jax.ShapeDtypeStruct(d.shape, self.cfg.dtype),
            self.defs, is_leaf=L.is_def)

    def loss(self, params, batch, chunk=512):
        return loss_fn(params, batch, self.cfg, chunk=chunk)

    def prefill(self, params, batch, window=0, chunk=512):
        return prefill(params, batch, self.cfg, window=window, chunk=chunk)

    def decode(self, params, cache, token, pos, window=0):
        return decode_step(params, cache, token, pos, self.cfg, window=window)

    def init_cache(self, batch, cache_len, enc_len=0, quantized=False):
        return init_cache(self.cfg, batch, cache_len, enc_len,
                          quantized=quantized)

    def param_count(self) -> int:
        import numpy as np
        return int(sum(np.prod(d.shape) for d in
                       jax.tree.leaves(self.defs, is_leaf=L.is_def)))
