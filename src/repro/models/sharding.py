"""Logical-axis -> PartitionSpec rules.

Parameters are annotated with *logical* axis names at creation time (see
``layers.py``); this module maps them onto the physical mesh axes:

  * ``model``-type logical axes (heads, ffn hidden, experts, vocab) shard over
    the ``"model"`` mesh axis — classic tensor parallelism.
  * When ``ArchConfig.fsdp`` is set, a second eligible dimension additionally
    shards over ``"data"`` (ZeRO-3-style weight sharding, needed for the
    >~70B-total-parameter assigned archs on 16 GB v5e chips).
  * Anything not divisible by the axis size stays replicated — GSPMD would pad
    uneven shards, wasting memory, so we only shard exact divisors.

The FL client axis (leading ``C`` on deltas/residuals) is handled separately in
``repro.core.aggregation`` and always maps to the client mesh axes.
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# logical axis name -> preference rank for receiving the "model" mesh axis.
# Lower = preferred. Exactly one dim per param gets "model"; with fsdp, one
# further dim (the best remaining candidate) gets "data".
_MODEL_PREF = {
    "experts": 0,      # expert parallelism first for MoE params
    "heads": 1,        # fused heads*head_dim projection dim
    "kv_heads": 1,
    "ffn": 1,          # FFN hidden
    "vocab": 2,
    "ssm_inner": 1,    # mamba d_inner
    "embed": 3,        # d_model — last resort
}
_FSDP_PREF = {
    "embed": 0,        # FSDP along d_model pairs well with TP along ffn/heads
    "ffn": 1,
    "vocab": 1,
    "heads": 2,
    "kv_heads": 2,
    "ssm_inner": 2,
    "experts": 3,
}
_NEVER = {"layers", "stack", None, "ssm_state", "ssm_heads", "conv", "pattern"}


FSDP_MODE = "extend"   # "extend" (default, §Perf C1) | "legacy"


def spec_for(shape: Sequence[int], logical: Sequence[str | None],
             mesh: Mesh, fsdp: bool) -> P:
    """Derive a PartitionSpec for one parameter from its logical axes.

    FSDP placement (§Perf pair-C finding): sharding a *contraction* dim over
    ``data`` clashes with batch-over-data and makes GSPMD re-gather the full
    weight and replicate compute across the model axis (16x flops on
    deepseek-67b). The ``extend`` mode instead (a) widens the model-sharded
    dim to ``("model","data")`` when divisible by both, else (b) shards the
    RIGHTMOST eligible (output) dim — never a pure contraction dim.
    """
    assert len(shape) == len(logical), (shape, logical)
    axes: list = [None] * len(shape)
    sizes = dict(mesh.shape)
    model_n = sizes.get("model", 1)
    data_n = sizes.get("data", 1)

    def pick(pref: Mapping[str, int], axis_size: int, taken: int | None):
        best, best_rank = None, 99
        for i, (dim, name) in enumerate(zip(shape, logical)):
            if i == taken or name in _NEVER or name not in pref:
                continue
            if dim % axis_size != 0 or axes[i] is not None:
                continue
            if pref[name] < best_rank:
                best, best_rank = i, pref[name]
        return best

    mi = pick(_MODEL_PREF, model_n, None) if model_n > 1 else None
    if mi is not None:
        axes[mi] = "model"
    if fsdp and data_n > 1:
        if FSDP_MODE == "legacy":
            di = pick(_FSDP_PREF, data_n, mi)
            if di is not None:
                axes[di] = "data"
        else:
            if mi is not None and shape[mi] % (model_n * data_n) == 0:
                axes[mi] = ("model", "data")
            else:
                for i in range(len(shape) - 1, -1, -1):
                    if (i != mi and logical[i] not in _NEVER
                            and logical[i] is not None
                            and shape[i] % data_n == 0 and axes[i] is None):
                        axes[i] = "data"
                        break
    return P(*axes)


def tree_specs(params: PyTree, logical_tree: PyTree, mesh: Mesh, fsdp: bool) -> PyTree:
    """Map ``spec_for`` over a (params, logical-axes) pytree pair."""
    return jax.tree.map(
        lambda p, lg: spec_for(np.shape(p), lg, mesh, fsdp),
        params, logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def with_prefix(specs: PyTree, *prefix: Any) -> PyTree:
    """Prepend mesh axes (e.g. the client axis) to every spec in a tree."""
    return jax.tree.map(lambda s: P(*prefix, *s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, client_axis: str) -> P:
    """Leading-axis spec for client-major batches: (clients, ...)."""
    names = mesh.axis_names
    if client_axis == "pod" and "pod" in names:
        return P("pod")
    if "pod" in names and client_axis == "data":
        return P(("pod", "data"))
    return P("data")


def n_clients(mesh: Mesh, client_axis: str) -> int:
    sizes = dict(mesh.shape)
    if client_axis == "pod":
        return sizes.get("pod", 1)
    return sizes.get("data", 1) * sizes.get("pod", 1)
