"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

TPU adaptation: the SSD *chunked* formulation is used — intra-chunk terms are
dense (L×L) matmuls that map onto the MXU, and the inter-chunk recurrence is a
short ``lax.scan`` over S/L steps.  This is the TPU-native form of the paper's
"dual" algorithm (no sequential per-token scan, no CUDA selective-scan port).

Shapes: x (B,S,D); internal x̃ (B,S,H,P) with H = d_inner / P heads,
B̃/C̃ (B,S,G,N) with G=1 group, state N = cfg.ssm_state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.models.layers import ParamDef, rmsnorm


def ssm_dims(cfg: ArchConfig):
    d_inner = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = 1
    conv_dim = d_inner + 2 * G * N
    d_in_proj = 2 * d_inner + 2 * G * N + H
    return d_inner, H, P, N, G, conv_dim, d_in_proj


def mamba_defs(cfg: ArchConfig):
    D = cfg.d_model
    d_inner, H, P, N, G, conv_dim, d_in_proj = ssm_dims(cfg)
    return {
        "ln": ParamDef((D,), ("norm",), "ones"),
        "in_proj": ParamDef((D, d_in_proj), ("embed", "ssm_inner")),
        "conv_w": ParamDef((cfg.ssm_conv_width, conv_dim), ("conv", "ssm_inner")),
        "conv_b": ParamDef((conv_dim,), ("ssm_inner",), "zeros"),
        "A_log": ParamDef((H,), ("ssm_heads",), "alog"),
        "D": ParamDef((H,), ("ssm_heads",), "ones"),
        "dt_bias": ParamDef((H,), ("ssm_heads",), "zeros"),
        "norm": ParamDef((d_inner,), ("norm",), "ones"),
        "out_proj": ParamDef((d_inner, D), ("ssm_inner", "embed")),
    }


def _split_proj(zxbcdt, cfg):
    d_inner, H, P, N, G, conv_dim, _ = ssm_dims(cfg)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xBC, dt


def _split_xbc(xBC, cfg):
    d_inner, H, P, N, G, _, _ = ssm_dims(cfg)
    x = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + G * N]
    Cm = xBC[..., d_inner + G * N:]
    B_, S = x.shape[0], x.shape[1]
    return (x.reshape(B_, S, H, P),
            Bm.reshape(B_, S, G, N),
            Cm.reshape(B_, S, G, N))


def causal_conv(xBC, w, b, cfg):
    """Depthwise causal conv, width W, via shifted adds (no conv primitive)."""
    W = cfg.ssm_conv_width
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, A, Bm, Cm, D, chunk):
    """Chunked SSD forward. x (B,S,H,P), dt (B,S,H), A (H,)<=0, Bm/Cm (B,S,G,N)."""
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L
    f32 = jnp.float32

    xc = x.reshape(B_, nc, L, H, P).astype(f32)
    dtc = dt.reshape(B_, nc, L, H).astype(f32)
    Bc = Bm.reshape(B_, nc, L, G, N).astype(f32)[..., 0, :]     # G=1 -> (B,nc,L,N)
    Cc = Cm.reshape(B_, nc, L, G, N).astype(f32)[..., 0, :]

    dA = dtc * A.astype(f32)                                    # (B,nc,L,H)  <=0
    A_cs = jnp.cumsum(dA, axis=2)                               # inclusive cumsum
    A_end = A_cs[:, :, -1:, :]                                  # (B,nc,1,H)

    # intra-chunk (dual / quadratic) term. The exponent is masked BEFORE the
    # exp: for j > i it is positive and can overflow, and grad-of-where
    # would propagate the resulting NaN even though the forward masks it.
    diff = A_cs[:, :, :, None, :] - A_cs[:, :, None, :, :]      # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.exp(jnp.where(mask[None, None, ..., None], diff, -1e9))
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                  # (B,nc,L,L)
    M = CB[..., None] * decay
    M = M * dtc[:, :, None, :, :]                               # weight by dt_j
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", M, xc)

    # chunk states: contribution of chunk c to the running state
    decay_end = jnp.exp(A_end - A_cs)                           # (B,nc,L,H)
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, decay_end * dtc, xc)

    # inter-chunk recurrence
    A_tot = jnp.exp(A_end[:, :, 0, :])                          # (B,nc,H)

    def step(h_prev, inputs):
        a_tot, s_c = inputs                                     # (B,H), (B,H,N,P)
        h = h_prev * a_tot[..., None, None] + s_c
        return h, h_prev

    h0 = jnp.zeros((B_, H, N, P), f32)
    _, h_prevs = jax.lax.scan(
        step, h0, (A_tot.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                  # (B,nc,H,N,P)

    y_off = jnp.einsum("bcin,bchnp,bcih->bcihp", Cc, h_prevs, jnp.exp(A_cs))
    y = (y_diag + y_off).reshape(B_, S, H, P) + D.astype(f32)[None, None, :, None] * x.astype(f32)
    return y.astype(x.dtype)


def mamba_block(p, x, cfg: ArchConfig):
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z, xBC, dt_raw = _split_proj(zxbcdt, cfg)
    xBC = causal_conv(xBC, p["conv_w"], p["conv_b"], cfg)
    xs, Bm, Cm = _split_xbc(xBC, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y = ssd_chunked(xs, dt, A, Bm, Cm, p["D"], cfg.ssm_chunk)
    B_, S = x.shape[0], x.shape[1]
    y = y.reshape(B_, S, -1)
    y = rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + y @ p["out_proj"]


# --- decode -----------------------------------------------------------------

def mamba_cache_defs(cfg: ArchConfig, batch):
    d_inner, H, P, N, G, conv_dim, _ = ssm_dims(cfg)
    return {
        "state": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), cfg.dtype),
    }


def mamba_decode(p, x, cfg: ArchConfig, cache):
    """x: (B,1,D) single-token step with constant-size state."""
    B_ = x.shape[0]
    d_inner, H, P, N, G, conv_dim, _ = ssm_dims(cfg)
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    z, xBC, dt_raw = _split_proj(h @ p["in_proj"], cfg)
    win = jnp.concatenate([cache["conv"], xBC.astype(cache["conv"].dtype)], axis=1)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", win, p["conv_w"]) + p["conv_b"])[:, None]
    new_conv = win[:, 1:]
    xs, Bm, Cm = _split_xbc(conv_out, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[:, 0] * A)                                  # (B,H)
    xb = jnp.einsum("bn,bhp->bhnp", Bm[:, 0, 0].astype(jnp.float32),
                    (dt[:, 0, :, None] * xs[:, 0].astype(jnp.float32)))
    state = cache["state"] * dA[..., None, None] + xb
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0, 0].astype(jnp.float32), state)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs[:, 0].astype(jnp.float32)
    y = y.reshape(B_, 1, d_inner).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + y @ p["out_proj"], {"state": state, "conv": new_conv}
