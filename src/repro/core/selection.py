"""Client selection (survey §III.B.2).

Selection is expressed as a per-round weight vector w ∈ R^C (0 for skipped
clients): under SPMD every client slot computes its local update regardless —
static shapes — and selection decides whose update (and whose wire bytes)
count. This matches how production FL simulators (and the sources' own
analyses) model partial participation.

  * ``all``              — full participation (FedAvg [6] default).
  * ``random``           — uniform m-of-C sampling (the baseline all selection
                           papers compare against).
  * ``power_of_choice``  — Cho et al. [54]: bias toward the highest local
                           *loss* among a random candidate set of size d.
  * ``multi_criteria``   — FedMCCS [50]: a composite resource score (CPU,
                           memory, energy, link quality — simulated device
                           profiles from the data pipeline) gates eligibility;
                           top-m eligible clients participate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import FLConfig


def _top_m_mask(scores, m):
    """Exactly-m selection mask. Rank-based: scatter 1s at the top_k
    *indices* rather than thresholding (``scores >= thresh`` over-selects
    whole tie groups at the cut). ``lax.top_k`` orders equal scores by
    ascending index, so ties break deterministically and the mask always
    has exactly m ones."""
    C = scores.shape[0]
    idx = jax.lax.top_k(scores, m)[1]
    return jnp.zeros((C,), jnp.float32).at[idx].set(1.0)


def select(cfg: FLConfig, rng, *, losses, resources, sizes,
           availability=None):
    """Returns per-client weights (C,) f32.

    losses       : (C,) local first-minibatch loss (power-of-choice signal)
    resources    : (C, R) in [0, 1] simulated device profile (FedMCCS signal)
    sizes        : (C,) client dataset sizes (FedAvg weighting)
    availability : optional (C,) {0,1} mask — clients sampled into the
                   cohort but offline this round (ClientPopulation churn);
                   they are zero-weighted whatever the selection policy
    """
    if availability is not None:
        sizes = sizes * availability
    C = sizes.shape[0]
    m = cfg.clients_per_round or C
    m = min(m, C)

    if cfg.selection == "all" or m == C:
        return sizes

    if cfg.selection == "random":
        mask = _top_m_mask(jax.random.uniform(rng, (C,)), m)
    elif cfg.selection == "power_of_choice":
        # candidate set of size d = min(C, 2m), then highest-loss m of them
        d = min(C, 2 * m)
        cand = _top_m_mask(jax.random.uniform(rng, (C,)), d)
        mask = _top_m_mask(jnp.where(cand > 0, losses, -jnp.inf), m)
    elif cfg.selection == "multi_criteria":
        score = resources.mean(axis=-1)
        # FedMCCS: clients whose predicted round time / energy qualify
        mask = _top_m_mask(score, m)
    else:
        raise ValueError(cfg.selection)
    return mask * sizes
