"""Version-compat shims for the jax APIs this repo rides.

``shard_map`` graduated from ``jax.experimental.shard_map`` (where its
replication check is spelled ``check_rep``) to top-level ``jax.shard_map``
(where it is spelled ``check_vma``). The repo targets the new spelling; on
older jax we fall back to the experimental module and translate the kwarg.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kw)


def make_mesh(axis_shapes, axis_names, **kw):
    """``jax.make_mesh`` with explicit-Auto axis types on jax versions that
    have ``jax.sharding.AxisType``; plain mesh (always Auto) on older ones."""
    if hasattr(jax.sharding, "AxisType"):
        kw.setdefault("axis_types",
                      (jax.sharding.AxisType.Auto,) * len(axis_names))
        return jax.make_mesh(axis_shapes, axis_names, **kw)
    kw.pop("axis_types", None)
    return jax.make_mesh(axis_shapes, axis_names, **kw)
