"""RoundEngine — ONE topology-agnostic FL round executor (DESIGN.md §5).

The survey's central claim is that FL cost is dominated by *rounds of
communication*, and that schemes must be compared across topologies
(client-server, hierarchical/edge, decentralized) under identical round
semantics. This module is where those semantics live — exactly once.

A round is a :class:`RoundProgram`: an ordered sequence of **hops**

    local-update -> encode -> transport -> decode -> aggregate
                 -> server-opt -> ledger

parameterized by a :class:`Topology`:

  * ``Topology.star(client_axis)``   — clients on mesh axes, shard_map
    aggregation (``core.federated`` deployment path);
  * ``Topology.hier(sync_every)``    — client -> edge(pod) -> cloud, periodic
    cross-pod sync (``core.hierarchical``);
  * ``Topology.gossip(graph)``       — decentralized ppermute ring mixing
    (``core.gossip``);
  * ``Topology.sim(n_clients)``      — single-device vmap simulator with the
    client count decoupled from the mesh (``core.simulate``).

``FLState.comm_state`` (CommPipeline-owned error-feedback residuals / DGC
momentum) is threaded generically through *every* wire hop — star, sim,
hierarchical edge, and gossip mix alike — so biased pipelines keep their
correction state on every topology as a structural consequence of the
engine, not a per-trainer patch.

On top of the per-round program, :func:`run_rounds` compiles ``chunk`` rounds
into a single donated-argument ``jax.lax.scan`` (per-round ``CommLedger`` /
metrics stacked out), replacing the Python round loop's per-round dispatch +
host sync in every driver (launch/train, benchmarks, examples).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compress.api import Identity, make_compressor
from repro.compress.pipeline import error_feedback, momentum_correction
from repro.compress.secure_agg import (DPNoise, MASK_TAG, SecAgg,
                                       bind_n_leaves, has_mask_ctx,
                                       inject_mask_ctx)
from repro.core import aggregation, selection as sel, server_opt
from repro.core import scenario as scn_mod
from repro.core.aggregation import comm_state_init, comm_state_specs
from repro.core.compat import shard_map
from repro.core.types import CommLedger, FLConfig, FLState
from repro.data.pipeline import capability_latency
from repro.models import sharding as shd
from repro.models.model import Model
from repro.obs import telemetry as obs_tel

PyTree = Any


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Topology:
    """Which shape the round's transport hops take.

    ``graph`` (gossip) is a tuple of ``(edge, mix_weight)`` entries where
    ``edge`` is either a ring offset (int — every node sends to
    ``(i+off) % C``) or an explicit permutation tuple of length C (fixed
    points ``sigma[i] == i`` do not send).  The per-node self-weight is
    whatever the incoming edge weights leave over; the builder asserts the
    resulting mixing matrix is doubly stochastic.  Use
    :func:`expander_graph` / :func:`erdos_renyi_graph` (or the
    ``Topology.gossip_*`` constructors) for non-ring graphs."""

    kind: str                          # star | hier | gossip | sim | async
    n_clients: int = 0                 # sim/async only (decoupled from mesh)
    sync_every: int = 4                # hier only (cloud hop period)
    graph: tuple = ((1, 0.25), (-1, 0.25))   # gossip only
    client_axis: str = ""              # star only ("" = from ArchConfig)
    buffer_size: int = 0               # async only: FedBuff K (0 = from
                                       # FLConfig, then C)
    staleness_alpha: float = None      # async only: (1+tau)^(-alpha) decay
                                       # (None = from FLConfig)
    latency_profile: str = ""          # async only ("" = from FLConfig)
    flush_deadline: float = None       # async only: virtual-clock flush
                                       # deadline (None = from FLConfig;
                                       # 0 = count-only FedBuff)

    @staticmethod
    def star(client_axis: str = "") -> "Topology":
        return Topology(kind="star", client_axis=client_axis)

    @staticmethod
    def hier(sync_every: int = 4) -> "Topology":
        return Topology(kind="hier", sync_every=sync_every)

    @staticmethod
    def gossip(graph=None) -> "Topology":
        return Topology(kind="gossip",
                        graph=tuple(graph) if graph else ((1, 0.25), (-1, 0.25)))

    @staticmethod
    def gossip_expander(n_clients: int, degree: int = 4) -> "Topology":
        return Topology.gossip(expander_graph(n_clients, degree))

    @staticmethod
    def gossip_er(n_clients: int, p: float = 0.5, seed: int = 0) -> "Topology":
        return Topology.gossip(erdos_renyi_graph(n_clients, p, seed))

    @staticmethod
    def sim(n_clients: int) -> "Topology":
        return Topology(kind="sim", n_clients=n_clients)

    @staticmethod
    def async_(n_clients: int, buffer_size: int = 0,
               staleness_alpha: float = None,
               latency_profile: str = "",
               flush_deadline: float = None) -> "Topology":
        """Virtual-clock asynchronous FL (core.async_engine, DESIGN.md §7):
        FedBuff buffering (``buffer_size`` K; 1 = FedAsync, 0/C = the
        degenerate synchronous limit), FedAsync staleness decay
        ``(1+tau)^(-staleness_alpha)``, per-dispatch latencies drawn from
        ``latency_profile`` over the FedMCCS device resource vectors, and
        adaptive buffer sizing via ``flush_deadline`` (> 0: also flush when
        the virtual clock passes the last flush + deadline, DESIGN.md §8).
        Knobs left at their sentinel (0 / None / \"\") fall back to the
        ``FLConfig.async_buffer_size / staleness_alpha / latency_profile /
        async_flush_deadline`` fields at engine build time."""
        return Topology(kind="async", n_clients=n_clients,
                        buffer_size=buffer_size,
                        staleness_alpha=staleness_alpha,
                        latency_profile=latency_profile,
                        flush_deadline=flush_deadline)


# ---------------------------------------------------------------------------
# Gossip graph constructors + the doubly-stochastic contract
# ---------------------------------------------------------------------------

def _graph_edges(spec, C: int):
    """Directed (src, dst) pairs for one graph entry: a ring offset (int) or
    an explicit permutation tuple (fixed points do not send)."""
    if isinstance(spec, (int, np.integer)):
        return [(i, (i + int(spec)) % C) for i in range(C)]
    sigma = tuple(int(s) for s in spec)
    if len(sigma) != C or sorted(sigma) != list(range(C)):
        raise ValueError(f"graph entry {spec!r} is not a permutation of "
                         f"range({C})")
    return [(i, sigma[i]) for i in range(C) if sigma[i] != i]


def mixing_matrix(graph, C: int) -> np.ndarray:
    """The dense (C, C) gossip mixing matrix W (row i mixes *into* node i):
    W[dst, src] += w per edge, and each node keeps whatever its incoming
    edge weights leave over (per-node self-weight)."""
    W = np.zeros((C, C))
    for spec, w in graph:
        for src, dst in _graph_edges(spec, C):
            W[dst, src] += float(w)
    np.fill_diagonal(W, np.diag(W) + 1.0 - W.sum(axis=1))
    return W


def check_doubly_stochastic(W: np.ndarray, atol: float = 1e-6) -> None:
    """Gossip averaging preserves the model mean and contracts to consensus
    iff W is doubly stochastic with non-negative entries — checked at engine
    build time for every graph."""
    if W.min() < -atol:
        raise ValueError(f"mixing matrix has negative entries "
                         f"(min {W.min():.4f}): edge weights too large — "
                         f"a node's incoming weights must sum to <= 1")
    for axis, name in ((1, "row"), (0, "column")):
        s = W.sum(axis=axis)
        if not np.allclose(s, 1.0, atol=atol):
            raise ValueError(f"mixing matrix {name} sums deviate from 1 "
                             f"(max |err| {np.abs(s - 1).max():.4f}) — "
                             f"graph is not doubly stochastic")


def expander_graph(n: int, degree: int = 4) -> tuple:
    """Circulant power-of-two expander: offsets ±1, ±2, ±4, ... with uniform
    weights 1/(E+1).  Each offset is a permutation, so the mix is a convex
    combination of permutation matrices — doubly stochastic by construction —
    with the log-diameter mixing of the hypercube family."""
    offs = []
    j = 0
    while len(offs) < degree and (1 << j) <= n // 2:
        o = 1 << j
        offs.append(o)
        if len(offs) < degree and (n - o) % n not in offs and n - o != o:
            offs.append(n - o)        # the symmetric (negative) offset
        j += 1
    w = 1.0 / (len(offs) + 1)
    return tuple((o, w) for o in offs)


def erdos_renyi_graph(n: int, p: float = 0.5, seed: int = 0) -> tuple:
    """Erdős–Rényi G(n, p) gossip graph: sample the undirected edge set,
    greedily edge-color it into matchings (each an involution permutation —
    ppermute-able), uniform edge weight 1/(max_degree + 1) so every node's
    self-weight stays non-negative (Metropolis-style) and W is symmetric
    doubly stochastic."""
    rng = np.random.default_rng(seed)
    upper = np.triu(rng.random((n, n)) < p, 1)
    edges = list(zip(*np.nonzero(upper)))
    deg = np.zeros(n, int)
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    if not edges:
        raise ValueError(f"G({n}, {p}) sample (seed={seed}) has no edges — "
                         f"raise p or change the seed")
    w = 1.0 / (deg.max() + 1)
    # greedy edge coloring: assign each edge the smallest color unused at
    # either endpoint; each color class is a matching
    used: list = [set() for _ in range(n)]
    matchings: list = []
    for i, j in edges:
        c = 0
        while c in used[i] or c in used[j]:
            c += 1
        used[i].add(c)
        used[j].add(c)
        while len(matchings) <= c:
            matchings.append(list(range(n)))
        matchings[c][i], matchings[c][j] = j, i
    return tuple((tuple(m), w) for m in matchings)


# ---------------------------------------------------------------------------
# RoundProgram: the hop sequence
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)          # identity hash: jit-able callable
class RoundProgram:
    """One FL round as an ordered sequence of named hops.

    Each hop is ``fn(ctx) -> ctx`` over a plain dict context; the program is
    traced once under jit so hop granularity costs nothing at runtime. The
    final hop must leave ``ctx["new_state"]`` / ``ctx["metrics"]``."""

    topology: Topology
    hops: tuple                        # ((name, fn), ...)

    def __call__(self, state: FLState, batch) -> tuple:
        ctx = {"state": state, "batch": batch}
        for _name, fn in self.hops:
            ctx = fn(ctx)
        return ctx["new_state"], ctx["metrics"]

    @property
    def hop_names(self) -> tuple:
        return tuple(name for name, _ in self.hops)


@dataclasses.dataclass
class RoundEngine:
    """A built round executor for one (model, fl, topology) binding."""
    topology: Topology
    program: RoundProgram
    round_fn: Any                      # (state, batch) -> (state, metrics)
    init_fn: Any                       # rng -> FLState
    n_clients: int
    terms: dict
    state_shardings: Any = None        # star/hier/gossip (mesh paths)
    batch_sharding_fn: Any = None      # star only
    programs: dict = dataclasses.field(default_factory=dict)
    # extra separately-compilable programs (e.g. hier edge / cloud steps,
    # kept distinct so the dry-run HLO keeps each collective set honest)
    aux: dict = dataclasses.field(default_factory=dict)
    # topology metadata (e.g. hier's n_pods / clients_per_pod)
    eval_every: int = 1
    # metrics_fn cadence inside run_rounds (FLConfig.eval_every)


# ---------------------------------------------------------------------------
# Uplink pipeline + static ledger terms (shared by every topology)
# ---------------------------------------------------------------------------

def uplink_pipeline(fl: FLConfig):
    """The uplink CommPipeline from config: the spec string (legacy name or
    ``"a:x>>b:y"`` chain) plus the stateful correction wrapper — DGC momentum
    correction if ``dgc_momentum`` is set (with the warm-up sparsity schedule
    when ``dgc_warmup_rounds`` > 0), else error feedback for biased
    pipelines. Wrappers leave wire/entropy bits unchanged."""
    if fl.dgc_warmup_rounds > 0 and fl.dgc_momentum <= 0.0:
        raise ValueError("dgc_warmup_rounds is a DGC knob — it needs "
                         "dgc_momentum > 0 to take effect")
    frac = fl.topk_fraction
    warmup = fl.dgc_warmup_rounds if fl.dgc_momentum > 0.0 else 0
    if warmup > 0:
        # DGC warm-up: round r transmits fraction f_target^((r+1)/(W+1)) —
        # the wire payload is sized for the first (widest) round and later
        # rounds mask down inside it (static shapes under jit).
        frac = fl.topk_fraction ** (1.0 / (warmup + 1.0))
    up = make_compressor(fl.uplink_compressor, fraction=frac,
                         block=fl.qsgd_block, rows=fl.sketch_rows,
                         cols=fl.sketch_cols, backend=fl.backend,
                         wire_format=fl.wire_format)
    if warmup > 0 and not up.is_identity:
        # the widened capacity must actually reach the wire: specs with an
        # explicit per-stage fraction ("topk:0.01>>...") override the
        # fraction kwarg and would silently make the warm-up a no-op
        at_target = make_compressor(fl.uplink_compressor,
                                    fraction=fl.topk_fraction,
                                    block=fl.qsgd_block, rows=fl.sketch_rows,
                                    cols=fl.sketch_cols, backend=fl.backend,
                                    wire_format=fl.wire_format)
        if up.wire_bits(1 << 16) == at_target.wire_bits(1 << 16):
            raise ValueError(
                "dgc_warmup_rounds needs a fraction-kwarg-driven uplink "
                f"spec (e.g. 'topk' + topk_fraction); "
                f"{fl.uplink_compressor!r} ignores the warm-up widening")
    up = _apply_privacy(fl, up)
    if fl.dgc_momentum > 0.0 and not up.is_identity:
        up = momentum_correction(up, fl.dgc_momentum,
                                 warmup_rounds=warmup,
                                 final_fraction=fl.topk_fraction)
    elif up.biased and fl.error_feedback:
        up = error_feedback(up)
    return up


def _apply_privacy(fl: FLConfig, up):
    """FLConfig privacy knobs as spec-suffix equivalents (DESIGN.md §11):
    dpnoise at the wire boundary first, secagg masking outermost (so the
    noised update is what gets quantized and masked). EF/DGC wrap outside
    privacy — residuals are computed from the *unmasked* decode, so they
    match the unmasked run bit-for-bit."""
    if fl.dp_sigma > 0.0 or fl.dp_clip > 0.0:
        clip = fl.dp_clip if fl.dp_clip > 0.0 else float("inf")
        up = DPNoise(up, fl.dp_sigma, clip)
    if fl.secure_agg and not up.is_identity:
        up = SecAgg(up)   # raises with the carrier rule for float pipelines
    return up


def _param_sizes(model: Model):
    """Flat per-leaf parameter counts (the ledger's byte-accounting basis)."""
    return [int(np.prod(d.shape)) for d in
            jax.tree.leaves(model.defs,
                            is_leaf=lambda x: hasattr(x, "logical"))]


def ledger_terms(model: Model, fl: FLConfig):
    """Static per-selected-client byte terms for the round ledger."""
    up = uplink_pipeline(fl)
    down = make_compressor(fl.downlink_compressor, block=fl.qsgd_block,
                           backend=fl.backend, wire_format=fl.wire_format)
    sizes = _param_sizes(model)
    # dpnoise splits its joint L2 clip budget across this model's leaves
    # (clip/sqrt(L) each) — binding L here keeps the billed rho=0.5/sigma^2
    # equal to what encode actually spends (DESIGN.md §11)
    bind_n_leaves(up, len(sizes))
    # SCAFFOLD ships control variates, FedDANE ships a gradient round: 2x
    scaff = 2.0 if fl.algorithm in ("scaffold", "feddane") else 1.0
    t = {
        "up_wire": scaff * sum(up.wire_bits(n) for n in sizes) / 8.0,
        "up_entropy": scaff * sum(up.entropy_bits(n) for n in sizes) / 8.0,
        "down_wire": sum(down.wire_bits(n) for n in sizes) / 8.0,
        "dense": sum(32.0 * n for n in sizes) / 8.0,
        # zCDP spent per selected client this round (0 unless dpnoise is in
        # the uplink); rides the ledger like bytes (DESIGN.md §11)
        "dp_rho": up.dp_rho_per_round(),
    }
    return t, up, down


def _telemetry_spec(fl: FLConfig, up, down, sizes):
    """The static per-stage byte spec when the flight recorder is on, else
    None (repro.obs.telemetry).  Scaled exactly like ``ledger_terms``:
    SCAFFOLD / FedDANE bill 2x on the uplink."""
    if not fl.telemetry:
        return None
    scaff = 2.0 if fl.algorithm in ("scaffold", "feddane") else 1.0
    return obs_tel.telemetry_spec(up, down, sizes, up_scale=scaff)


def _make_ledger(terms: dict, n_sel) -> CommLedger:
    led = CommLedger(
        uplink_wire=n_sel * terms["up_wire"],
        uplink_entropy=n_sel * terms["up_entropy"],
        downlink_wire=n_sel * terms["down_wire"],
        uplink_dense=n_sel * terms["dense"],
        downlink_dense=n_sel * terms["dense"],
    )
    if terms.get("dp_rho", 0.0):
        led = dataclasses.replace(led, dp_rho=n_sel * jnp.float32(
            terms["dp_rho"]))
    return led


# ---------------------------------------------------------------------------
# Client local update (shared by every topology)
# ---------------------------------------------------------------------------

def _client_update(model: Model, fl: FLConfig, params, batch_c, rng,
                   control, c_i, chunk, global_grad=None, n_steps=None):
    """One client's local training. Returns (delta, mean_loss, first_loss,
    new_c_i). For ``feddane`` [49], ``global_grad`` is the aggregated
    gradient at the global params; the local steps use the DANE-corrected
    gradient g_i(w') + (g(w) − g_i(w)) + mu·(w' − w).

    ``n_steps`` (scalar int32, scenario epoch scaling) truncates the local
    solve to the first ``n_steps`` of the ``local_steps`` scan iterations:
    the scan keeps its static length (shape discipline) and later steps
    freeze the client params behind a ``jnp.where`` — same per-step
    arithmetic, statically absent when ``n_steps is None``."""
    E, lr = fl.local_steps, fl.local_lr
    loss_fn = lambda p: model.loss(p, batch_c, chunk=chunk)[0]

    ddt = jnp.bfloat16 if fl.delta_dtype == "bf16" else jnp.float32
    fast = (E == 1 and fl.algorithm in ("fedavg", "fedsgd")
            and fl.fedprox_mu == 0.0)
    if fast:
        loss, g = jax.value_and_grad(loss_fn)(params)
        delta = jax.tree.map(lambda g_: (-lr * g_).astype(ddt), g)
        return delta, loss, loss, c_i

    dane_corr = None
    if fl.algorithm == "feddane" and global_grad is not None:
        g_i0 = jax.grad(loss_fn)(params)
        dane_corr = jax.tree.map(
            lambda gg, gi: gg.astype(jnp.float32) - gi.astype(jnp.float32),
            global_grad, g_i0)

    def step(p_c, _):
        loss, g = jax.value_and_grad(loss_fn)(p_c)
        if fl.algorithm in ("fedprox", "feddane") and fl.fedprox_mu:
            g = jax.tree.map(
                lambda g_, pc, p0: g_ + fl.fedprox_mu * (pc - p0).astype(g_.dtype),
                g, p_c, params)
        if dane_corr is not None:
            g = jax.tree.map(lambda g_, d: g_ + d.astype(g_.dtype),
                             g, dane_corr)
        if fl.algorithm == "scaffold":
            g = jax.tree.map(
                lambda g_, c, ci: g_ + (c - ci).astype(g_.dtype), g, control, c_i)
        p_c = jax.tree.map(lambda a, g_: (a.astype(jnp.float32)
                                          - lr * g_.astype(jnp.float32)
                                          ).astype(a.dtype), p_c, g)
        return p_c, loss

    if n_steps is None:
        p_fin, losses = jax.lax.scan(step, params, None, length=E)
        mean_loss = losses.mean()
    else:
        def gated(p_c, j):
            p_new, loss = step(p_c, None)
            active = j < n_steps
            p_c = jax.tree.map(
                lambda old, new: jnp.where(active, new, old), p_c, p_new)
            return p_c, jnp.where(active, loss, 0.0)
        p_fin, losses = jax.lax.scan(gated, params, jnp.arange(E))
        # n_steps >= 1 always (scenario.epoch_steps floors it), so step 0
        # is active and losses[0] stays the selection hop's first loss
        mean_loss = losses.sum() / n_steps.astype(jnp.float32)
    delta = jax.tree.map(
        lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32))
        .astype(ddt), p_fin, params)
    new_c_i = c_i
    if fl.algorithm == "scaffold":
        new_c_i = jax.tree.map(
            lambda ci, c, d: ci - c - d / (E * lr), c_i, control, delta)
    return delta, mean_loss, losses[0], new_c_i


# ---------------------------------------------------------------------------
# The shared dispatch body (DESIGN.md §8) — downlink >> local-update vmap >>
# wire-boundary optimization_barrier >> CommPipeline encode/decode.  Both the
# synchronous sim/star hops and the AsyncEngine's generation dispatch run
# THESE functions, so the degenerate async == sync bit-exactness contract is
# structural: a change to the sync wire is, by construction, a change to the
# async wire (there is no second copy to diverge).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)          # identity hash: jit-able callable
class Dispatch:
    """One dispatch generation, decomposed so programs can interleave their
    topology-specific hops (selection, CMFL, SCAFFOLD control) between the
    shared stages:

      * ``downlink(params, k_down)`` — LFL-quantised global broadcast;
      * ``local_update(params, model_batch, k_loc)`` — the batched client
        vmap -> ``(deltas, mean_losses, first_losses)``;
      * ``wire_rows(deltas, comm_state, k_up)`` — the wire boundary: one
        ``optimization_barrier`` materializing the deltas, then the batched
        CommPipeline encode/decode -> ``((C,)-led decoded rows, new
        comm_state)``;
      * ``aggregate_rows(rows, w_num, wsum)`` — barrier + weighted mean of
        decoded rows (the sync wire aggregates rows it just decoded, the
        async flush aggregates rows buffered from earlier events — the
        barrier pins both to the same materialization, DESIGN.md §7/§8).

    ``__call__`` composes the first three — the AsyncEngine's whole
    per-generation computation.

    ``epoch_steps(batch) -> (n_steps, scale)`` (scenario epoch scaling,
    DESIGN.md §13) is attached only when the scenario enables it — every
    caller gates on ``epoch_steps is not None`` at build time, so the OFF
    graph is byte-identical to a dispatch built without a scenario."""

    downlink: Callable
    local_update: Callable
    wire_rows: Callable
    aggregate_rows: Callable
    n_clients: int
    epoch_steps: Optional[Callable] = None

    @staticmethod
    def model_batch(batch) -> dict:
        """Model inputs only (FL metadata keys stay out of the loss vmap)."""
        return {k: v for k, v in batch.items()
                if k not in ("sizes", "resources", "ids")}

    def __call__(self, params, batch, comm_state, k_loc, k_down, k_up):
        params = self.downlink(params, k_down)
        if self.epoch_steps is not None:
            n_steps, _ = self.epoch_steps(batch)
            deltas, losses, _ = self.local_update(
                params, self.model_batch(batch), k_loc, n_steps)
        else:
            deltas, losses, _ = self.local_update(
                params, self.model_batch(batch), k_loc)
        rows, new_comm = self.wire_rows(deltas, comm_state, k_up)
        return rows, losses, new_comm


def make_dispatch(model: Model, fl: FLConfig, up, down, C: int,
                  chunk: int, scenario=None) -> Dispatch:
    """Build the shared dispatch body for one (model, fl) binding over ``C``
    vmapped clients with uplink pipeline ``up`` / downlink ``down``.
    ``scenario`` (a :class:`repro.core.scenario.Scenario`) with
    ``epoch_scale > 0`` attaches the heterogeneity-aware per-client
    local-step budget; any other scenario knob leaves the dispatch body
    untouched (availability/dropout act on aggregation weights in the
    round programs)."""
    stateful = up.stateful
    masked = has_mask_ctx(up)

    def downlink(params, k_down):
        if down.is_identity:
            return params
        return jax.tree.map(
            lambda p: down.roundtrip(k_down,
                                     p.reshape(-1).astype(jnp.float32))
            .reshape(p.shape).astype(p.dtype), params)

    def local_update(params, model_batch, k_loc, n_steps=None):
        rngs = jax.random.split(k_loc, C)
        if n_steps is None:
            deltas, losses, first_losses, _ = jax.vmap(
                lambda b, r: _client_update(
                    model, fl, params, b, r, None, None,
                    chunk))(model_batch, rngs)
        else:
            deltas, losses, first_losses, _ = jax.vmap(
                lambda b, r, ns: _client_update(
                    model, fl, params, b, r, None, None, chunk,
                    n_steps=ns))(model_batch, rngs, n_steps)
        return deltas, losses, first_losses

    epoch_steps = None
    if scenario is not None and scenario.epoch_scale > 0.0:
        if fl.local_steps <= 1:
            raise ValueError(
                "scenario epoch scaling needs local_steps > 1 — there is "
                "no per-client budget to truncate at a single local step")
        if fl.algorithm not in ("fedavg", "fedsgd", "fedprox"):
            raise ValueError(
                f"scenario epoch scaling truncates the local scan per "
                f"client — the {fl.algorithm!r} control-variate bookkeeping "
                f"assumes a fixed step count; use fedavg/fedsgd/fedprox")

        def epoch_steps(batch):
            res = batch.get("resources", jnp.ones((C, 4), jnp.float32))
            return scn_mod.epoch_steps(scenario, fl.local_steps, res)

    def wire_rows(deltas, comm_state, k_up):
        # The wire boundary: materialize the client deltas BEFORE encoding —
        # without the barrier XLA fuses e.g. the E=1 delta multiply into the
        # error-feedback residual add as an FMA, and a consumer that receives
        # the delta materialized in an earlier program (the AsyncEngine's
        # buffered rows) could never reproduce the arithmetic (DESIGN.md §7)
        deltas = jax.lax.optimization_barrier(deltas)
        rngs_up = jax.random.split(k_up, C)
        dec_rows, st_rows = [], []
        for li, leaf in enumerate(jax.tree.leaves(deltas)):
            shape = leaf.shape[1:]
            flat = leaf.reshape(C, -1).astype(jnp.float32)
            rs = jax.vmap(lambda r: jax.random.fold_in(r, li))(rngs_up)
            if stateful:
                if masked:
                    # secagg context for this hop: a round/leaf-shared mask
                    # key, the client's vmap lane as ring index, cohort C.
                    # Injected fresh each dispatch, so async re-dispatches
                    # (flush) re-key their masks with their own k_up.
                    mkey = jax.random.fold_in(
                        jax.random.fold_in(k_up, MASK_TAG), li)

                    def one(x, r, st, i, mkey=mkey):
                        st = inject_mask_ctx(st, mkey, i, C)
                        payload, nst = up.encode(st, r, x)
                        return up.decode(payload, x.shape[0]), nst
                    dec, nst = jax.vmap(one)(
                        flat, rs, comm_state[li],
                        jnp.arange(C, dtype=jnp.int32))
                else:
                    def one(x, r, st):
                        payload, nst = up.encode(st, r, x)
                        return up.decode(payload, x.shape[0]), nst
                    dec, nst = jax.vmap(one)(flat, rs, comm_state[li])
                st_rows.append(nst)
            else:
                def one(x, r):
                    payload, _ = up.encode(up.init(x.shape), r, x)
                    return up.decode(payload, x.shape[0])
                dec = jax.vmap(one)(flat, rs)
            dec_rows.append(dec.reshape((C,) + shape))
        dec_tree = jax.tree.unflatten(jax.tree.structure(deltas), dec_rows)
        return dec_tree, (tuple(st_rows) if stateful else None)

    def aggregate_rows(rows, w_num, wsum):
        # materialize the decoded rows before aggregating — the sync wire
        # feeds rows straight out of wire_rows, the AsyncEngine feeds rows
        # committed by earlier events; the barrier makes the weighted mean
        # lower identically in both programs (bit-exact degenerate
        # equivalence, DESIGN.md §7)
        rows = jax.lax.optimization_barrier(rows)
        return jax.tree.map(
            lambda leaf: ((w_num[:, None] * leaf.reshape(C, -1)).sum(0)
                          / wsum).reshape(leaf.shape[1:]), rows)

    return Dispatch(downlink=downlink, local_update=local_update,
                    wire_rows=wire_rows, aggregate_rows=aggregate_rows,
                    n_clients=C, epoch_steps=epoch_steps)


# ---------------------------------------------------------------------------
# Wire implementations (encode -> transport -> decode -> aggregate), one per
# topology.  Every one threads the pipeline comm_state.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Wire:
    """Transport hop bundle for the server topologies (star / sim)."""
    aggregate: Callable        # (deltas(C,..), weights, rng, comm_state)
    #                            -> (agg, new_comm_state)
    aggregate_dense: Callable  # (tree(C,..), weights, rng) -> agg  (SCAFFOLD)
    needs_ids: bool = False    # population wires take the cohort ids too:
    #                            aggregate(..., comm_state, ids)


def _star_wire(mesh, pspecs, up, client_axis, abs_params, need_dense) -> _Wire:
    aggregate = aggregation.make_aggregator(mesh, pspecs, up, client_axis,
                                            abstract_params=abs_params)
    agg_dense = None
    if need_dense:
        dense = aggregation.make_aggregator(mesh, pspecs, Identity(),
                                            client_axis)
        agg_dense = lambda t, w, r: dense(t, w, r, None)[0]
    return _Wire(aggregate=aggregate, aggregate_dense=agg_dense)


def _sim_wire(dispatch: Dispatch, C) -> _Wire:
    """Single-device wire, built ON the shared dispatch body: encode/decode
    rows via ``dispatch.wire_rows`` and the weighted mean via
    ``dispatch.aggregate_rows`` — the same two functions the AsyncEngine
    runs, so sync and async cannot silently diverge (DESIGN.md §8).
    Pipeline state (EF residual / DGC momentum) rides along with a leading
    C dim."""

    def aggregate(deltas, weights, rng, comm_state):
        rows, new_comm = dispatch.wire_rows(deltas, comm_state, rng)
        wsum = jnp.maximum(weights.sum(), 1e-9)
        return dispatch.aggregate_rows(rows, weights, wsum), new_comm

    def aggregate_dense(tree, weights, rng):
        wsum = jnp.maximum(weights.sum(), 1e-9)
        return jax.tree.map(
            lambda a: (weights.reshape((C,) + (1,) * (a.ndim - 1)) * a)
            .sum(0) / wsum, tree)

    return _Wire(aggregate=aggregate, aggregate_dense=aggregate_dense)


def _population_wire(dispatch: Dispatch, store, M: int) -> _Wire:
    """Sim wire over a sampled cohort with store-backed pipeline state
    (DESIGN.md §9).  ``comm_state`` is the ResidualStore dict, not dense
    (C,)-led rows: the cohort's rows are **gathered** at the dispatch
    boundary, advanced by the same ``dispatch.wire_rows`` the dense wire
    runs, and **scattered** back at the commit (the wire hop is the commit
    point for synchronous rounds — the server has irrevocably consumed the
    payload, so the residual advance is final).  With ``capacity >=
    n_clients`` and ``cohort == n_clients`` gather/scatter are identities
    and this wire is bit-exact vs :func:`_sim_wire`."""

    def aggregate(deltas, weights, rng, comm_state, ids):
        rows_in, st = store.gather(comm_state, ids)
        rows, new_rows = dispatch.wire_rows(deltas, rows_in, rng)
        st = store.scatter(st, ids, new_rows)
        wsum = jnp.maximum(weights.sum(), 1e-9)
        return dispatch.aggregate_rows(rows, weights, wsum), st

    def aggregate_dense(tree, weights, rng):
        wsum = jnp.maximum(weights.sum(), 1e-9)
        return jax.tree.map(
            lambda a: (weights.reshape((M,) + (1,) * (a.ndim - 1)) * a)
            .sum(0) / wsum, tree)

    return _Wire(aggregate=aggregate, aggregate_dense=aggregate_dense,
                 needs_ids=True)


def _star_population_wire(base: _Wire, store) -> _Wire:
    """Star wire over a population: gather the cohort's store rows OUTSIDE
    the shard_map collective, run the unchanged stateful aggregator on them
    (it treats its ``comm_state`` argument as (C,)-led rows and returns the
    advanced rows), then scatter the advance back into the store."""

    def aggregate(deltas, weights, rng, comm_state, ids):
        rows_in, st = store.gather(comm_state, ids)
        agg, new_rows = base.aggregate(deltas, weights, rng, rows_in)
        st = store.scatter(st, ids, new_rows)
        return agg, st

    return _Wire(aggregate=aggregate, aggregate_dense=base.aggregate_dense,
                 needs_ids=True)


# ---------------------------------------------------------------------------
# The server-topology round (star + sim share this body verbatim)
# ---------------------------------------------------------------------------

def _fl_scenario(fl: FLConfig):
    """The FLConfig's scenario, or None when every knob is at its default —
    the builders thread None so all scenario hops are statically absent
    (the conformance contract, tests/test_scenario.py)."""
    scn = scn_mod.Scenario.from_fl(fl)
    return scn if scn.enabled else None


def _attach_scenario(population, scenario):
    """Give the population the scenario's availability trace (its mask and
    the selection hop then share one schedule).  The population keeps its
    own duty rate; a no-op without a scenario or when the caller already
    attached one."""
    if (scenario is None or population is None
            or population.scenario is not None):
        return population
    return dataclasses.replace(population, scenario=scenario)


def _build_server_program(model: Model, fl: FLConfig, topo: Topology,
                          wire: _Wire, terms: dict, dispatch: Dispatch,
                          C: int, chunk: int,
                          population=None, tele=None,
                          store=None, scenario=None) -> RoundProgram:
    scaffold = fl.algorithm == "scaffold"
    simulator = topo.kind == "sim"

    def hop_rng(ctx):
        st = ctx["state"]
        rng, r_down, r_sel, r_up, r_next = jax.random.split(st.rng, 5)
        ctx.update(rng=rng, r_down=r_down, r_sel=r_sel, r_up=r_up,
                   r_next=r_next)
        return ctx

    def hop_cohort(ctx):
        # this round's client ids — pure in (population.seed, round), so the
        # data pipeline (cohort_data_fn) independently computes the SAME ids
        ctx["ids"] = population.cohort_ids(ctx["state"].round)
        return ctx

    def hop_downlink(ctx):
        # downlink (LFL): clients train from a quantised global model —
        # the shared dispatch body's downlink stage (DESIGN.md §8)
        ctx["params"] = dispatch.downlink(ctx["state"].params, ctx["r_down"])
        return ctx

    def hop_dane_gradient(ctx):
        # FedDANE [49]: one extra communication round — aggregate the global
        # gradient at w before the corrected local solves (ledger counts 2x)
        gg = None
        if simulator and fl.algorithm == "feddane":
            params = ctx["params"]
            g_each = jax.vmap(lambda b: jax.grad(
                lambda p: model.loss(p, b, chunk=chunk)[0])(params))(
                ctx["model_batch"])
            gg = jax.tree.map(lambda g: g.astype(jnp.float32).mean(0), g_each)
        ctx["global_grad"] = gg
        return ctx

    def hop_model_batch(ctx):
        ctx["model_batch"] = Dispatch.model_batch(ctx["batch"])
        return ctx

    def hop_local_update(ctx):
        st, params = ctx["state"], ctx["params"]
        if scaffold:
            rngs = jax.random.split(ctx["rng"], C)
            deltas, losses, first_losses, new_ci = jax.vmap(
                lambda b, r, ci: _client_update(model, fl, params, b, r,
                                                st.control, ci, chunk))(
                ctx["model_batch"], rngs, st.client_controls)
        elif ctx["global_grad"] is not None:
            # FedDANE's corrected solve carries the extra aggregated
            # gradient — the one per-client signature the shared body
            # doesn't take (async rejects feddane for the same reason)
            rngs = jax.random.split(ctx["rng"], C)
            deltas, losses, first_losses, _ = jax.vmap(
                lambda b, r: _client_update(model, fl, params, b, r,
                                            None, None, chunk,
                                            global_grad=ctx["global_grad"]))(
                ctx["model_batch"], rngs)
            new_ci = None
        elif dispatch.epoch_steps is not None:
            # scenario epoch scaling (DESIGN.md §13): the dispatch body's
            # local-update stage with per-client step budgets from the
            # FedMCCS capability profile
            n_steps, escale = dispatch.epoch_steps(ctx["batch"])
            deltas, losses, first_losses = dispatch.local_update(
                params, ctx["model_batch"], ctx["rng"], n_steps)
            ctx["scn_escale"] = escale
            new_ci = None
        else:
            # the shared dispatch body's local-update stage (DESIGN.md §8)
            deltas, losses, first_losses = dispatch.local_update(
                params, ctx["model_batch"], ctx["rng"])
            new_ci = None
        ctx.update(deltas=deltas, losses=losses, first_losses=first_losses,
                   new_ci=new_ci)
        return ctx

    def hop_select(ctx):
        batch = ctx["batch"]
        sizes = batch.get("sizes", jnp.ones((C,), jnp.float32))
        resources = batch.get("resources", jnp.ones((C, 4), jnp.float32))
        avail = None
        if population is not None and population.availability_active:
            # per-(id, round) dropout of sampled clients — statically
            # skipped at availability == 1.0 with a static trace (the
            # degenerate contract).  The mask comes from the ONE shared
            # implementation in core.scenario via the population.
            avail = population.availability_mask(ctx["state"].round,
                                                 ctx["ids"])
        elif (population is None and scenario is not None
              and scenario.availability_on):
            # dense sim/star path: the same shared trace over the static
            # client slots (ids are the vmap lanes)
            avail = scn_mod.availability_mask(
                scenario, scenario.seed, scenario.availability,
                ctx["state"].round, jnp.arange(C, dtype=jnp.int32))
        weights = sel.select(fl, ctx["r_sel"], losses=ctx["first_losses"],
                             resources=resources, sizes=sizes,
                             availability=avail)
        ctx["weights"] = weights
        if avail is not None:
            ctx["avail"] = avail
        return ctx

    def hop_scenario_dropout(ctx):
        # mid-round dropout (DESIGN.md §13): a per-client survival draw
        # against the round's elapsed virtual time (the deterministic
        # capability latency).  Dropped clients become zero-weight rows in
        # Dispatch.aggregate_rows — partial-update semantics, payload
        # shapes untouched; under secagg the decode unmasks per client via
        # the payload ctx, so zero-weighting is the existing recover path
        # (tests/test_secure_agg.py).  Appended only when the scenario's
        # dropout hazard is > 0 (the OFF graph has no such hop).
        batch = ctx["batch"]
        res = batch.get("resources", jnp.ones((C, 4), jnp.float32))
        lat = capability_latency(res)
        ids = ctx.get("ids")
        if ids is None:
            ids = jnp.arange(C, dtype=jnp.int32)
        survive = scn_mod.survival_mask(scenario, ctx["state"].round,
                                        ids, lat)
        selected_before = (ctx["weights"] > 0).astype(jnp.float32)
        ctx["weights"] = ctx["weights"] * survive
        ctx["scn_dropped"] = (selected_before * (1.0 - survive)).sum()
        return ctx

    def hop_cmfl(ctx):
        # CMFL [35]: drop updates whose sign-agreement with the previous
        # global update falls below the threshold (they are "irrelevant" and
        # never uploaded — the ledger sees the reduced n_sel). Sim path.
        st, deltas, weights = ctx["state"], ctx["deltas"], ctx["weights"]
        d_flat = jnp.concatenate([l.reshape(C, -1) for l in
                                  jax.tree.leaves(deltas)], axis=1)
        p_flat = jnp.concatenate([l.reshape(-1) for l in
                                  jax.tree.leaves(st.prev_delta)])
        rel = (jnp.sign(d_flat) == jnp.sign(p_flat)[None, :]).mean(axis=1)
        rel = jnp.where(st.round == 0, 1.0, rel)       # warm-up round
        ctx["weights"] = weights * (rel >= fl.cmfl_threshold)
        return ctx

    def hop_wire(ctx):
        # encode -> transport -> decode -> aggregate; comm_state rides along.
        # The wire-boundary optimization_barrier lives in the shared dispatch
        # body (Dispatch.wire_rows — the sim wire is built on it); the star
        # wire's shard_map aggregator encodes inside the collective, so it
        # materializes the deltas here instead (same boundary, DESIGN.md §8)
        deltas = (ctx["deltas"] if simulator
                  else jax.lax.optimization_barrier(ctx["deltas"]))
        weights = ctx["weights"]
        n_sel = (weights > 0).sum().astype(jnp.float32)
        if wire.needs_ids:
            agg, new_comm = wire.aggregate(deltas, weights, ctx["r_up"],
                                           ctx["state"].comm_state,
                                           ctx["ids"])
        else:
            agg, new_comm = wire.aggregate(deltas, weights, ctx["r_up"],
                                           ctx["state"].comm_state)
        ctx.update(agg=agg, new_comm=new_comm, n_sel=n_sel)
        return ctx

    def hop_control(ctx):
        # SCAFFOLD control-variate bookkeeping: unselected clients keep c_i
        st, weights = ctx["state"], ctx["weights"]
        selmask = (weights > 0).astype(jnp.float32)
        new_ci = jax.tree.map(
            lambda new, old: jnp.where(
                selmask.reshape((C,) + (1,) * (new.ndim - 1)) > 0, new, old),
            ctx["new_ci"], st.client_controls)
        dci = jax.tree.map(lambda a, b: a - b, new_ci, st.client_controls)
        agg_dc = wire.aggregate_dense(dci, weights, ctx["r_up"])
        control = jax.tree.map(
            lambda c, d: c + (ctx["n_sel"] / C) * d, st.control, agg_dc)
        ctx.update(new_ci=new_ci, control=control)
        return ctx

    def hop_server_opt(ctx):
        st = ctx["state"]
        new_params, new_sos = server_opt.apply(fl, st.params, ctx["agg"],
                                               st.server_opt_state)
        ctx.update(new_params=new_params, new_sos=new_sos)
        return ctx

    def hop_ledger(ctx):
        billed = ctx["n_sel"]
        if scenario is not None and scenario.dropout > 0.0:
            # a mid-round-dropped client already shipped its payload (the
            # row is zero-weighted at aggregation, not withheld — under
            # secagg its masked codes MUST arrive for the masks to
            # cancel), so billing stays at the pre-dropout selection
            billed = billed + ctx["scn_dropped"]
        ctx["billed"] = billed
        ctx["ledger"] = _make_ledger(terms, billed)
        return ctx

    def hop_telemetry(ctx):
        # flight recorder (repro.obs, DESIGN.md §12): reads already-computed
        # round values + static byte terms only — params / comm_state /
        # ledger are untouched, so the telemetry-off graph is the exact
        # subgraph with this hop removed (tests/test_obs.py)
        ctrs = (store.stats(ctx["state"].comm_state, ctx["ids"])
                if store is not None else None)
        if population is not None:
            available = population.availability_count(ctx["state"].round,
                                                      ctx["ids"])
        elif "avail" in ctx:
            available = ctx["avail"].sum()
        else:
            available = jnp.float32(C)
        ctx["round_stats"] = obs_tel.round_stats(
            tele, ctx["ledger"], up_unit=ctx["billed"], store=ctrs,
            selected=ctx["n_sel"], available=available,
            avail_duty=available / jnp.float32(C),
            dropped=ctx.get("scn_dropped"),
            epoch_scale=ctx.get("scn_escale"))
        return ctx

    def hop_finalize(ctx):
        st, weights, losses = ctx["state"], ctx["weights"], ctx["losses"]
        wsum = jnp.maximum(weights.sum(), 1e-9)
        metrics = {
            "loss": (weights * losses).sum() / wsum,
            "loss_all": losses.mean(),
            "selected": ctx["n_sel"],
            "ledger": ctx["ledger"],
        }
        if tele is not None:
            metrics["round_stats"] = ctx["round_stats"]
        new_prev = ctx["agg"] if (simulator and fl.cmfl_threshold > 0) else None
        ctx["new_state"] = FLState(
            params=ctx["new_params"], server_opt_state=ctx["new_sos"],
            control=ctx.get("control"), client_controls=ctx["new_ci"],
            comm_state=ctx["new_comm"], rng=ctx["r_next"],
            round=st.round + 1, prev_delta=new_prev,
        )
        ctx["metrics"] = metrics
        return ctx

    hops = [("rng", hop_rng)]
    if population is not None:
        hops.append(("cohort", hop_cohort))
    hops += [("downlink", hop_downlink),
             ("model_batch", hop_model_batch),
             ("dane_gradient", hop_dane_gradient),
             ("local_update", hop_local_update), ("select", hop_select)]
    if scenario is not None and scenario.dropout > 0.0:
        hops.append(("scenario_dropout", hop_scenario_dropout))
    if simulator and fl.cmfl_threshold > 0:
        hops.append(("cmfl", hop_cmfl))
    hops.append(("wire", hop_wire))
    if scaffold:
        hops.append(("control", hop_control))
    hops += [("server_opt", hop_server_opt), ("ledger", hop_ledger)]
    if tele is not None:
        hops.append(("telemetry", hop_telemetry))
    hops.append(("finalize", hop_finalize))
    return RoundProgram(topology=topo, hops=tuple(hops))


# ---------------------------------------------------------------------------
# star / sim engine builders
# ---------------------------------------------------------------------------

def _build_star(model: Model, fl: FLConfig, topo: Topology, mesh: Mesh,
                chunk: int, population=None) -> RoundEngine:
    cfg = model.cfg
    client_axis = topo.client_axis or cfg.client_axis
    axes = aggregation.client_axes(mesh, client_axis)
    C = int(np.prod([dict(mesh.shape)[a] for a in axes])) if axes else 1
    client_p = P(axes) if axes else P()

    abs_params = model.abstract_params()
    pspecs = shd.tree_specs(abs_params, model.logical_axes(),
                            mesh, cfg.fsdp)
    terms, up, down = ledger_terms(model, fl)
    scaffold = fl.algorithm == "scaffold"
    stateful = up.stateful
    scenario = _fl_scenario(fl)
    population = _attach_scenario(population, scenario)
    store = None
    if population is not None:
        if scaffold:
            raise ValueError(
                "scaffold keeps dense (C, model) client controls — "
                "incompatible with a streaming ClientPopulation")
        if population.cohort != C:
            raise ValueError(
                f"star topology dispatches one cohort slot per mesh client "
                f"({C}); got population.cohort={population.cohort}")
        store = population.make_store(up, abs_params)
    dispatch = make_dispatch(model, fl, up, down, C, chunk,
                             scenario=scenario)
    wire = _star_wire(mesh, pspecs, up, client_axis, abs_params,
                      need_dense=scaffold)
    if store is not None:
        wire = _star_population_wire(wire, store)

    clientful = shd.with_prefix(pspecs, axes if axes else None)
    state_specs = FLState(
        params=pspecs,
        server_opt_state={k: pspecs
                          for k in server_opt.state_keys(fl.server_opt)},
        control=pspecs if scaffold else None,
        client_controls=clientful if scaffold else None,
        comm_state=(store.specs() if store is not None
                    else comm_state_specs(up, abs_params, pspecs, axes)
                    if stateful else None),
        rng=P(), round=P(),
    )
    state_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P))

    def init_fn(rng):
        params = model.init(rng)
        zerosf32 = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zeros_clientful = lambda: jax.tree.map(
            lambda p: jnp.zeros((C,) + p.shape, jnp.float32), params)
        return FLState(
            params=params,
            server_opt_state=server_opt.init_state(fl.server_opt, params),
            control=zerosf32() if scaffold else None,
            client_controls=zeros_clientful() if scaffold else None,
            comm_state=(store.init() if store is not None
                        else comm_state_init(up, params, C)
                        if stateful else None),
            rng=jax.random.PRNGKey(fl.seed),
            round=jnp.zeros((), jnp.int32),
        )

    def batch_sharding_fn(batch):
        """Client dim -> client axes; for pod-clients the within-client batch
        dim additionally shards over the data axis."""
        out = {}
        sub = ("data",) if (client_axis == "pod"
                            and "data" in mesh.axis_names) else ()
        lead = tuple(client_p) or (None,)
        for k, v in batch.items():
            nd = np.ndim(v) if not hasattr(v, "ndim") else v.ndim
            if nd == 0:
                out[k] = NamedSharding(mesh, P())
            elif nd <= 2 or not sub:
                # (C,) / (C, small) metadata: client axes only
                out[k] = NamedSharding(mesh, P(*lead))
            else:
                # (C, B, ...) model inputs: within-client batch over data
                out[k] = NamedSharding(mesh, P(*lead, *sub))
        return out

    tele = _telemetry_spec(fl, up, down, _param_sizes(model))
    program = _build_server_program(model, fl, topo, wire, terms, dispatch,
                                    C, chunk, population=population,
                                    tele=tele, store=store,
                                    scenario=scenario)
    aux = {}
    if population is not None:
        aux["population"] = population
    if tele is not None:
        aux["telemetry"] = tele
    return RoundEngine(
        topology=topo, program=program, round_fn=program,
        init_fn=init_fn, n_clients=C, terms=terms,
        state_shardings=state_shardings,
        batch_sharding_fn=batch_sharding_fn,
        aux=aux,
    )


def _build_sim(model: Model, fl: FLConfig, topo: Topology,
               chunk: int, population=None) -> RoundEngine:
    C = topo.n_clients
    terms, up, down = ledger_terms(model, fl)
    scaffold = fl.algorithm == "scaffold"
    stateful = up.stateful
    scenario = _fl_scenario(fl)
    population = _attach_scenario(population, scenario)
    store = None
    if population is not None:
        if scaffold:
            raise ValueError(
                "scaffold keeps dense (C, model) client controls — "
                "incompatible with a streaming ClientPopulation")
        if population.n_clients != C:
            raise ValueError(
                f"population.n_clients ({population.n_clients}) must match "
                f"Topology.sim(n_clients={C})")
        C = population.cohort           # dispatch width = the cohort slice
        store = population.make_store(up, model.abstract_params())
    dispatch = make_dispatch(model, fl, up, down, C, chunk,
                             scenario=scenario)
    if store is not None:
        wire = _population_wire(dispatch, store, C)
    else:
        wire = _sim_wire(dispatch, C)

    def init_fn(rng):
        params = model.init(rng)
        zc = lambda: jax.tree.map(
            lambda p: jnp.zeros((C,) + p.shape, jnp.float32), params)
        zf = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return FLState(
            params=params,
            server_opt_state=server_opt.init_state(fl.server_opt, params),
            control=zf() if scaffold else None,
            client_controls=zc() if scaffold else None,
            comm_state=(store.init() if store is not None
                        else comm_state_init(up, params, C)
                        if stateful else None),
            rng=jax.random.PRNGKey(fl.seed),
            round=jnp.zeros((), jnp.int32),
            prev_delta=zf() if fl.cmfl_threshold > 0 else None,
        )

    tele = _telemetry_spec(fl, up, down, _param_sizes(model))
    program = _build_server_program(model, fl, topo, wire, terms, dispatch,
                                    C, chunk, population=population,
                                    tele=tele, store=store,
                                    scenario=scenario)
    aux = {}
    if population is not None:
        aux.update(population=population, cohort=C)
    if tele is not None:
        aux["telemetry"] = tele
    return RoundEngine(topology=topo, program=program, round_fn=program,
                       init_fn=init_fn, n_clients=topo.n_clients,
                       terms=terms, aux=aux)


# ---------------------------------------------------------------------------
# hierarchical engine (client -> edge(pod) -> cloud)
# ---------------------------------------------------------------------------

def _build_hier(model: Model, fl: FLConfig, topo: Topology, mesh: Mesh,
                chunk: int) -> RoundEngine:
    assert "pod" in mesh.axis_names, "hierarchical FL needs a pod axis"
    assert fl.algorithm != "scaffold", \
        "hierarchical topology keeps no server control-variate state; " \
        "use fedavg/fedsgd/fedprox (or the star topology for SCAFFOLD)"
    cfg = model.cfg
    sizes = dict(mesh.shape)
    G, Ce = sizes["pod"], sizes["data"]

    abs_params = model.abstract_params()
    pspecs = shd.tree_specs(abs_params, model.logical_axes(), mesh, cfg.fsdp)
    gspecs = shd.with_prefix(pspecs, "pod")                  # (G, ...) params
    dspecs = shd.with_prefix(pspecs, "pod", "data")          # (G, Ce, ...)

    # edge hop uses the full uplink pipeline (EF / DGC wrappers included —
    # comm_state threads through the edge hop, closing the stateless gap)
    up = uplink_pipeline(fl)
    pod_comp = make_compressor(fl.pod_compressor, block=fl.qsgd_block,
                               backend=fl.backend,
                               wire_format=fl.wire_format)
    stateful = up.stateful

    nparams = _param_sizes(model)
    bind_n_leaves(up, len(nparams))   # dpnoise: joint clip over all leaves
    terms = {
        "edge_wire": sum(up.wire_bits(n) for n in nparams) / 8.0 * Ce * G,
        "cloud_wire": sum(pod_comp.wire_bits(n) for n in nparams) / 8.0 * G,
        "dense": sum(32.0 * n for n in nparams) / 8.0 * Ce * G,
    }
    # One TelemetrySpec serves BOTH cond branches (lax.cond needs identical
    # output structure): edge stages are static per-round bytes, and the
    # appended pod slot is the residual against the branch's own ledger —
    # ~0 on edge rounds, ~cloud_wire on cloud rounds.
    tele = None
    if fl.telemetry:
        tele = obs_tel.telemetry_spec(
            up, None, nparams, up_scale=float(Ce * G),
            extra_up=((f"pod:{fl.pod_compressor}", terms["cloud_wire"]),))

    # (G, Ce) client grid: one leading dim per (pod, data) axis
    comm_specs = (comm_state_specs(up, abs_params, pspecs, ("pod", "data"),
                                   separate=True)
                  if stateful else None)

    # ------------------------------------------------------------------ agg
    def _agg_edge(deltas, weights, rng, comm_state):
        """Edge hop: within-pod aggregation. deltas (G, Ce, ...), weights
        (G, Ce) replicated -> per-pod mean delta (G, ...). Pipeline state
        (EF residual / DGC momentum) has (G, Ce) leading dims and stays on
        its client's devices — only the payload crosses the ICI."""
        def body(dtree, w, comm):
            gi = jax.lax.axis_index("pod")
            ci = jax.lax.axis_index("data")
            out, st_out = [], []
            for li, leaf in enumerate(jax.tree.leaves(dtree)):
                flat = leaf.reshape(-1).astype(jnp.float32)
                r = jax.random.fold_in(jax.random.fold_in(rng, li),
                                       gi * Ce + ci)
                if up.is_identity:
                    contrib = w[gi, ci] * flat
                    edge = jax.lax.psum(contrib, "data") / \
                        jnp.maximum(jax.lax.psum(w[gi, ci], "data"), 1e-9)
                else:
                    st = (jax.tree.map(lambda a: a[0, 0], comm[li])
                          if stateful else up.init(flat.shape))
                    if has_mask_ctx(up):
                        # per-pod mask ring over the "data" axis (the edge
                        # cohort): pods mask independently, cohort = Ce
                        mkey = jax.random.fold_in(jax.random.fold_in(
                            jax.random.fold_in(rng, MASK_TAG), li), gi)
                        st = inject_mask_ctx(st, mkey, ci, Ce)
                    payload, new_st = up.encode(st, r, flat)
                    gath = jax.lax.all_gather(payload, "data")
                    dec = jax.vmap(lambda q: up.decode(q, flat.shape[0]))(gath)
                    wrow = w[gi]
                    edge = (wrow[:, None] * dec).sum(0) / \
                        jnp.maximum(wrow.sum(), 1e-9)
                    if stateful:
                        st_out.append(jax.tree.map(lambda a: a[None, None],
                                                   new_st))
                out.append(edge.reshape((1,) + leaf.shape[2:])
                           .astype(leaf.dtype))
            agg = jax.tree.unflatten(jax.tree.structure(dtree), out)
            return agg, (tuple(st_out) if stateful else ())

        if stateful:
            return shard_map(body, mesh=mesh,
                             in_specs=(dspecs, P(), comm_specs),
                             out_specs=(gspecs, comm_specs),
                             check_vma=False)(deltas, weights, comm_state)
        agg = shard_map(lambda d, w: body(d, w, None)[0], mesh=mesh,
                        in_specs=(dspecs, P()),
                        out_specs=gspecs, check_vma=False)(deltas, weights)
        return agg, None

    def _sync_models(params, rng):
        """Cloud hop: periodic *model* averaging across pods (FedPAQ /
        Hier-Local-QSGD), quantised with ``pod_compressor``. All pods leave
        with the identical synced model."""
        def body(ptree):
            out = []
            for li, leaf in enumerate(jax.tree.leaves(ptree)):
                flat = leaf.reshape(-1).astype(jnp.float32)
                r = jax.random.fold_in(rng, li)
                if pod_comp.is_identity:
                    synced = jax.lax.pmean(flat, "pod")
                else:
                    pay, _ = pod_comp.encode(
                        pod_comp.init(flat.shape),
                        jax.random.fold_in(r, jax.lax.axis_index("pod")), flat)
                    gath = jax.lax.all_gather(pay, "pod")
                    dec = jax.vmap(lambda q: pod_comp.decode(
                        q, flat.shape[0]))(gath)
                    synced = dec.mean(0)
                out.append(synced.reshape(leaf.shape).astype(leaf.dtype))
            return jax.tree.unflatten(jax.tree.structure(ptree), out)

        return shard_map(body, mesh=mesh, in_specs=(gspecs,),
                         out_specs=gspecs, check_vma=False)(params)

    def _pod_divergence(params):
        """Mean squared distance of per-pod models from their mean — the
        periodic-averaging 'staleness' the cloud hop resets.

        Probed on a fixed small slice of the largest leaf: an exact
        full-parameter version costs a full-model pod all-reduce per round
        (measured: +16.4 GB/dev on qwen32b — more than the FL wire itself),
        so the metric must not dominate the step it measures."""
        leaves = sorted(jax.tree.leaves(params), key=lambda l: -l.size)
        probe = leaves[0].reshape(leaves[0].shape[0], -1)[:, :4096]
        probe = probe.astype(jnp.float32)
        return jnp.mean((probe - probe.mean(0, keepdims=True)) ** 2)

    # ------------------------------------------------------------------ hops
    def _make_program(cloud: bool) -> RoundProgram:
        def hop_rng(ctx):
            st = ctx["state"]
            r_loc, r_up, r_next = jax.random.split(st.rng, 3)
            ctx.update(r_loc=r_loc, r_up=r_up, r_next=r_next)
            return ctx

        def hop_local_update(ctx):
            st = ctx["state"]
            rngs = jax.random.split(ctx["r_loc"], G * Ce).reshape(G, Ce, -1)
            model_batch = {k: v for k, v in ctx["batch"].items()
                           if k != "sizes"}
            deltas, losses = jax.vmap(lambda pg, bg, rg: jax.vmap(
                lambda bc, rc: _client_update(
                    model, fl, pg, bc, rc, None, None, chunk)[:2])(bg, rg))(
                st.params, model_batch, rngs)
            ctx.update(deltas=deltas, losses=losses)
            return ctx

        def hop_wire(ctx):
            weights = ctx["batch"].get("sizes",
                                       jnp.ones((G, Ce), jnp.float32))
            agg, new_comm = _agg_edge(ctx["deltas"], weights, ctx["r_up"],
                                      ctx["state"].comm_state)
            ctx.update(agg=agg, new_comm=new_comm)
            return ctx

        def hop_server_opt(ctx):
            # per-pod server update (vmap-free: tree ops broadcast over G)
            st = ctx["state"]
            new_params, new_sos = server_opt.apply(fl, st.params, ctx["agg"],
                                                   st.server_opt_state)
            ctx.update(new_params=new_params, new_sos=new_sos)
            return ctx

        def hop_cloud_sync(ctx):
            # periodic model averaging across pods
            ctx["new_params"] = _sync_models(
                ctx["new_params"], jax.random.fold_in(ctx["r_up"], 99))
            return ctx

        def hop_ledger(ctx):
            wire = terms["edge_wire"] + (terms["cloud_wire"] if cloud else 0.0)
            ctx["ledger"] = CommLedger(
                uplink_wire=jnp.float32(wire),
                uplink_entropy=jnp.float32(wire),
                downlink_wire=jnp.float32(0.0),
                uplink_dense=jnp.float32(terms["dense"]),
                downlink_dense=jnp.float32(0.0))
            rho = up.dp_rho_per_round()
            if rho:
                ctx["ledger"] = dataclasses.replace(
                    ctx["ledger"], dp_rho=jnp.float32(rho * Ce * G))
            return ctx

        def hop_telemetry(ctx):
            ctx["round_stats"] = obs_tel.round_stats(
                tele, ctx["ledger"], up_unit=jnp.float32(1.0),
                selected=jnp.float32(Ce * G), available=jnp.float32(Ce * G))
            return ctx

        def hop_finalize(ctx):
            st = ctx["state"]
            ctx["metrics"] = {
                "loss": ctx["losses"].mean(),
                "ledger": ctx["ledger"],
                "pod_divergence": _pod_divergence(ctx["new_params"]),
            }
            if tele is not None:
                ctx["metrics"]["round_stats"] = ctx["round_stats"]
            ctx["new_state"] = FLState(
                params=ctx["new_params"], server_opt_state=ctx["new_sos"],
                control=None, client_controls=None,
                comm_state=ctx["new_comm"], rng=ctx["r_next"],
                round=st.round + 1,
            )
            return ctx

        hops = [("rng", hop_rng), ("local_update", hop_local_update),
                ("edge_wire", hop_wire), ("server_opt", hop_server_opt)]
        if cloud:
            hops.append(("cloud_sync", hop_cloud_sync))
        hops.append(("ledger", hop_ledger))
        if tele is not None:
            hops.append(("telemetry", hop_telemetry))
        hops.append(("finalize", hop_finalize))
        return RoundProgram(topology=topo, hops=tuple(hops))

    edge_program = _make_program(cloud=False)
    cloud_program = _make_program(cloud=True)

    def round_fn(state, batch):
        """Scan-safe round: cloud sync every ``sync_every`` rounds via cond
        (the dry-run still lowers edge/cloud as two separate programs)."""
        is_cloud = (state.round + 1) % topo.sync_every == 0
        return jax.lax.cond(is_cloud, cloud_program, edge_program,
                            state, batch)

    def init_fn(rng):
        params = model.init(rng)
        params = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (G,) + p.shape), params)
        return FLState(
            params=params,
            server_opt_state=server_opt.init_state(fl.server_opt, params),
            control=None, client_controls=None,
            comm_state=(comm_state_init(up, model.abstract_params(), (G, Ce))
                        if stateful else None),
            rng=jax.random.PRNGKey(fl.seed),
            round=jnp.zeros((), jnp.int32),
        )

    state_specs = FLState(
        params=gspecs,
        server_opt_state={k: gspecs
                          for k in server_opt.state_keys(fl.server_opt)},
        control=None, client_controls=None,
        comm_state=comm_specs, rng=P(), round=P(),
    )
    state_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P))

    return RoundEngine(
        topology=topo, program=edge_program, round_fn=round_fn,
        init_fn=init_fn, n_clients=G * Ce, terms=terms,
        state_shardings=state_shardings,
        programs={"edge": edge_program, "cloud": cloud_program},
        aux={"n_pods": G, "clients_per_pod": Ce,
             **({"telemetry": tele} if tele is not None else {})},
    )


# ---------------------------------------------------------------------------
# gossip engine (decentralized ring mixing)
# ---------------------------------------------------------------------------

def _build_gossip(model: Model, fl: FLConfig, topo: Topology, mesh: Mesh,
                  chunk: int) -> RoundEngine:
    cfg = model.cfg
    C = dict(mesh.shape)["data"]
    # biased compressors gossip with error feedback riding in comm_state —
    # but NOT DGC momentum correction: DGC accumulates update *deltas*,
    # while the gossip mix ships raw model parameters (accumulating those
    # diverges), so that knob is rejected for this topology
    if fl.dgc_momentum > 0.0:
        raise ValueError(
            "dgc_momentum accumulates update deltas; the gossip mix ships "
            "raw model parameters — use error feedback (the default for "
            "biased pipelines) instead")
    comp = make_compressor(fl.uplink_compressor, fraction=fl.topk_fraction,
                           block=fl.qsgd_block, rows=fl.sketch_rows,
                           cols=fl.sketch_cols, backend=fl.backend,
                           wire_format=fl.wire_format)
    comp = _apply_privacy(fl, comp)
    if comp.biased and fl.error_feedback:
        comp = error_feedback(comp)
    stateful = comp.stateful

    abs_params = model.abstract_params()
    pspecs = shd.tree_specs(abs_params, model.logical_axes(), mesh, cfg.fsdp)
    cspecs = shd.with_prefix(pspecs, "data")

    # general graphs: ring offsets and/or explicit permutations (expander /
    # Erdős–Rényi matchings). Every node keeps whatever its incoming edge
    # weights leave over; the mixing matrix must be doubly stochastic.
    check_doubly_stochastic(mixing_matrix(topo.graph, C))
    perms = [(_graph_edges(spec, C), w) for spec, w in topo.graph]
    # per-node self weight = 1 - sum of weights over edges INTO that node
    # (un-targeted ppermute destinations receive zeros, so a node skipped
    # by a matching keeps its own share)
    self_w_vec = np.full((C,), 1.0)
    for edges, w in perms:
        for _, dst in edges:
            self_w_vec[dst] -= w
    self_w_vec = jnp.asarray(self_w_vec, jnp.float32)

    nparams = _param_sizes(model)
    bind_n_leaves(comp, len(nparams))  # dpnoise: joint clip over all leaves
    payload_bytes = sum(comp.wire_bits(n) for n in nparams) / 8.0
    n_edges = sum(len(edges) for edges, _ in perms)
    terms = {
        # every payload crossing a directed graph edge counts once
        "mix_wire": payload_bytes * n_edges,
        "dense": sum(32.0 * n for n in nparams) / 8.0 * n_edges,
    }
    # the ledger's mix_wire is absolute (already x n_edges), so the spec is
    # scaled the same way and round_stats anchors with up_unit=1.0
    tele = (obs_tel.telemetry_spec(comp, None, nparams,
                                   up_scale=float(n_edges))
            if fl.telemetry else None)

    comm_specs = (comm_state_specs(comp, abs_params, pspecs, ("data",))
                  if stateful else None)

    def mix(params, rng, comm_state):
        def body(ptree, comm):
            self_w = self_w_vec[jax.lax.axis_index("data")]
            out, st_out = [], []
            for li, leaf in enumerate(jax.tree.leaves(ptree)):
                flat = leaf.reshape(-1).astype(jnp.float32)
                r = jax.random.fold_in(rng, li)
                st = (jax.tree.map(lambda a: a[0], comm[li])
                      if stateful else comp.init(flat.shape))
                if has_mask_ctx(comp):
                    # gossip: the ring spans all C nodes. Cancellation only
                    # holds for sums over the full cohort, so masked gossip
                    # is exact when the mixing row covers every node (all-to
                    # -all matchings); sparse matchings decode per-edge via
                    # the payload ctx, which stays exact per client.
                    mkey = jax.random.fold_in(
                        jax.random.fold_in(rng, MASK_TAG), li)
                    st = inject_mask_ctx(
                        st, mkey, jax.lax.axis_index("data"), C)
                payload, new_st = comp.encode(st, r, flat)
                n = flat.shape[0]
                mixed = self_w * flat
                for perm, w in perms:
                    nb = jax.lax.ppermute(payload, "data", perm)
                    mixed = mixed + w * comp.decode(nb, n)
                out.append(mixed.reshape(leaf.shape).astype(leaf.dtype))
                if stateful:
                    st_out.append(jax.tree.map(lambda a: a[None], new_st))
            tree = jax.tree.unflatten(jax.tree.structure(ptree), out)
            return tree, (tuple(st_out) if stateful else ())

        if stateful:
            return shard_map(body, mesh=mesh,
                             in_specs=(cspecs, comm_specs),
                             out_specs=(cspecs, comm_specs),
                             check_vma=False)(params, comm_state)
        mixed = shard_map(lambda p: body(p, None)[0], mesh=mesh,
                          in_specs=(cspecs,),
                          out_specs=cspecs, check_vma=False)(params)
        return mixed, None

    def hop_rng(ctx):
        st = ctx["state"]
        r_mix, r_next = jax.random.split(st.rng)
        ctx.update(r_mix=r_mix, r_next=r_next)
        return ctx

    def hop_local_update(ctx):
        st = ctx["state"]

        def local(p_c, batch_c):
            loss, g = jax.value_and_grad(
                lambda p: model.loss(p, batch_c, chunk=chunk)[0])(p_c)
            p_c = jax.tree.map(
                lambda a, g_: (a.astype(jnp.float32)
                               - fl.local_lr * g_.astype(jnp.float32)
                               ).astype(a.dtype), p_c, g)
            return p_c, loss

        params, losses = jax.vmap(local)(st.params, ctx["batch"])
        ctx.update(params=params, losses=losses)
        return ctx

    def hop_mix(ctx):
        params, new_comm = mix(ctx["params"], ctx["r_mix"],
                               ctx["state"].comm_state)
        ctx.update(params=params, new_comm=new_comm)
        return ctx

    def hop_ledger(ctx):
        ctx["ledger"] = CommLedger(
            uplink_wire=jnp.float32(terms["mix_wire"]),
            uplink_entropy=jnp.float32(terms["mix_wire"]),
            downlink_wire=jnp.float32(0.0),
            uplink_dense=jnp.float32(terms["dense"]),
            downlink_dense=jnp.float32(0.0))
        rho = comp.dp_rho_per_round()
        if rho:
            # every node releases one noised payload per round
            ctx["ledger"] = dataclasses.replace(
                ctx["ledger"], dp_rho=jnp.float32(rho * C))
        return ctx

    def hop_telemetry(ctx):
        ctx["round_stats"] = obs_tel.round_stats(
            tele, ctx["ledger"], up_unit=jnp.float32(1.0),
            selected=jnp.float32(C), available=jnp.float32(C))
        return ctx

    def hop_finalize(ctx):
        st, params = ctx["state"], ctx["params"]
        # consensus error (mean squared distance to the mean model)
        leaves = jax.tree.leaves(params)
        consensus = sum(
            jnp.sum((l.astype(jnp.float32)
                     - l.astype(jnp.float32).mean(0, keepdims=True)) ** 2)
            for l in leaves) / sum(l.size for l in leaves)
        ctx["metrics"] = {"loss": ctx["losses"].mean(),
                          "consensus": consensus,
                          "ledger": ctx["ledger"]}
        if tele is not None:
            ctx["metrics"]["round_stats"] = ctx["round_stats"]
        ctx["new_state"] = FLState(
            params=params, server_opt_state={},
            control=None, client_controls=None,
            comm_state=ctx["new_comm"], rng=ctx["r_next"],
            round=st.round + 1,
        )
        return ctx

    hops = [("rng", hop_rng), ("local_update", hop_local_update),
            ("mix", hop_mix), ("ledger", hop_ledger)]
    if tele is not None:
        hops.append(("telemetry", hop_telemetry))
    hops.append(("finalize", hop_finalize))
    program = RoundProgram(topology=topo, hops=tuple(hops))

    def init_fn(rng):
        p = model.init(rng)
        ps = jax.tree.map(lambda a: jnp.broadcast_to(a, (C,) + a.shape), p)
        return FLState(
            params=ps, server_opt_state={},
            control=None, client_controls=None,
            comm_state=(comm_state_init(comp, p, C) if stateful else None),
            rng=jax.random.PRNGKey(fl.seed),
            round=jnp.zeros((), jnp.int32),
        )

    state_specs = FLState(params=cspecs, server_opt_state={},
                          control=None, client_controls=None,
                          comm_state=comm_specs, rng=P(), round=P())
    state_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P))

    return RoundEngine(topology=topo, program=program, round_fn=program,
                       init_fn=init_fn, n_clients=C, terms=terms,
                       state_shardings=state_shardings,
                       aux=({"telemetry": tele} if tele is not None else {}))


# ---------------------------------------------------------------------------
# public builder
# ---------------------------------------------------------------------------

# above this client count a dense sim/async build would silently allocate
# O(C x model) comm_state rows (plus (C,)-wide dispatch) — the build refuses
# and points at the streaming path instead (DESIGN.md §9)
POPULATION_DENSE_LIMIT = 4096


def _check_population(fl: FLConfig, topology: Topology) -> None:
    C = topology.n_clients
    if C <= POPULATION_DENSE_LIMIT:
        return
    if topology.kind == "sim" and not uplink_pipeline(fl).stateful:
        return      # stateless sim keeps no per-client rows; C-wide is legal
    raise ValueError(
        f"{topology.kind} topology with n_clients={C} would allocate dense "
        f"per-client state — O(C x model) comm_state rows for the stateful "
        f"uplink pipeline"
        + (" and a (C x model) update buffer"
           if topology.kind == "async" else "")
        + f" — above the {POPULATION_DENSE_LIMIT}-client dense limit. "
        f"Pass a streaming population instead: "
        f"make_round_engine(..., population=ClientPopulation("
        f"n_clients={C}, cohort=1024)) (core.population; CLI: "
        f"--population {C} --cohort 1024), which bounds per-client state "
        f"by the residual-store capacity (DESIGN.md §9).")


def make_round_engine(model: Model, fl: FLConfig, topology: Topology,
                      mesh: Optional[Mesh] = None,
                      chunk: int = 512, data_fn=None,
                      population=None) -> RoundEngine:
    """Build the round executor for one (model, fl, topology) binding.

    The four legacy factories (``make_fl_train_step``,
    ``make_hier_fl_train_step``, ``make_gossip_step``, ``make_sim_step``)
    are thin wrappers over this.  The ``async`` topology additionally needs
    ``data_fn(version) -> batch`` at build time: its event scan samples each
    dispatch generation's batches internally, keyed on server version
    (core.async_engine, DESIGN.md §7).

    ``population`` (a :class:`repro.core.population.ClientPopulation`)
    switches the sim / async / star paths to streaming-cohort dispatch:
    each round touches only ``population.cohort`` sampled clients and
    per-client pipeline state lives in a bounded residual store
    (DESIGN.md §9).  Dense builds above ``POPULATION_DENSE_LIMIT`` clients
    are rejected."""
    if population is not None and topology.kind in ("hier", "gossip"):
        raise ValueError(
            f"{topology.kind} topology pins every client to a mesh device — "
            f"a streaming ClientPopulation only applies to star/sim/async")
    if topology.kind in ("hier", "gossip") and _fl_scenario(fl) is not None:
        raise ValueError(
            f"scenario client dynamics (FLConfig.scenario_*) thread through "
            f"the star/sim/async round programs; the {topology.kind} "
            f"topology has no per-client selection/weighting hop to mask")
    if topology.kind == "star":
        assert mesh is not None, "star topology needs a mesh"
        engine = _build_star(model, fl, topology, mesh, chunk,
                             population=population)
    elif topology.kind == "hier":
        assert mesh is not None, "hier topology needs a mesh"
        engine = _build_hier(model, fl, topology, mesh, chunk)
    elif topology.kind == "gossip":
        assert mesh is not None, "gossip topology needs a mesh"
        engine = _build_gossip(model, fl, topology, mesh, chunk)
    elif topology.kind == "sim":
        assert topology.n_clients > 0, "sim topology needs n_clients"
        if population is None:
            _check_population(fl, topology)
        engine = _build_sim(model, fl, topology, chunk,
                            population=population)
    elif topology.kind == "async":
        assert topology.n_clients > 0, "async topology needs n_clients"
        if population is None:
            _check_population(fl, topology)
        from repro.core.async_engine import build_async_engine
        engine = build_async_engine(model, fl, topology, data_fn, chunk,
                                    population=population)
    else:
        raise ValueError(f"unknown topology kind {topology.kind!r}")
    engine.eval_every = max(1, int(fl.eval_every))
    return engine


# ---------------------------------------------------------------------------
# run_rounds: the scan-compiled multi-round driver
# ---------------------------------------------------------------------------

def _gated_metrics(metrics_fn, state, metrics, do):
    """Run ``metrics_fn`` only when ``do`` (a traced bool) — the eval-cadence
    gate. The skipped branch keeps every base-metric leaf that survives
    ``metrics_fn`` structurally unchanged (same path/shape/dtype — the round
    loss and CommLedger must exist every round) and fills eval-only leaves
    with NaN (0 for integer dtypes), so both ``lax.cond`` branches return one
    pytree structure."""
    tmpl = jax.eval_shape(metrics_fn, state, metrics)
    base = {path: leaf for path, leaf in
            jax.tree_util.tree_flatten_with_path(metrics)[0]}

    def on(_):
        return metrics_fn(state, metrics)

    def off(_):
        leaves = []
        for path, t in jax.tree_util.tree_flatten_with_path(tmpl)[0]:
            b = base.get(path)
            if b is not None and b.shape == t.shape and b.dtype == t.dtype:
                leaves.append(b)
            else:
                fill = (jnp.nan if jnp.issubdtype(t.dtype, jnp.floating)
                        else 0)
                leaves.append(jnp.full(t.shape, fill, t.dtype))
        return jax.tree.unflatten(jax.tree.structure(tmpl), leaves)

    return jax.lax.cond(do, on, off, None)


class RoundRunner:
    """Compiles ``chunk`` rounds into one donated-argument ``jax.lax.scan``.

    The round index fed to ``data_fn`` is ``state.round`` (incremented by the
    round program), so batches are sampled *inside* the scan — one XLA
    program per chunk shape, no per-round dispatch or host sync.
    ``metrics_fn(new_state, metrics)`` (optional) appends extra per-round
    metrics (e.g. a held-out eval loss) inside the compiled program.

    ``eval_every`` (default: the engine's ``FLConfig.eval_every``) gates
    ``metrics_fn`` behind a ``lax.cond`` so the eval cost is paid only on
    every ``eval_every``-th round — the *last* round of each cadence window
    (``round % eval_every == eval_every - 1``), so a run whose length is a
    multiple of the cadence always evaluates its final round. Skipped
    rounds keep the base round metrics and NaN-fill the eval-only leaves."""

    def __init__(self, engine: RoundEngine, data_fn, chunk: int = 8,
                 metrics_fn=None, donate: bool = True, eval_every=None,
                 tracer=None):
        self.engine = engine
        self.data_fn = data_fn
        self.chunk = max(1, chunk)
        self.metrics_fn = metrics_fn
        self.tracer = tracer
        self.eval_every = max(1, int(engine.eval_every if eval_every is None
                                     else eval_every))
        ee = self.eval_every
        round_fn = engine.round_fn

        def body(state, _):
            batch = data_fn(state.round)
            new_state, metrics = round_fn(state, batch)
            if metrics_fn is not None:
                if ee == 1:
                    metrics = metrics_fn(new_state, metrics)
                else:
                    metrics = _gated_metrics(
                        metrics_fn, new_state, metrics,
                        state.round % ee == ee - 1)
            return new_state, metrics

        def run_chunk(state, k: int):
            return jax.lax.scan(body, state, None, length=k)

        # Mesh paths (star/hier/gossip) pin the state's output shardings to
        # the engine's declared NamedShardings.  Without the pin, XLA
        # normalizes equivalent-but-unequal specs (P(None, None) -> P())
        # on the way out, the donated output feeds chunk 2 with a sharding
        # that no longer compares equal to chunk 1's input, and the
        # identical chunk shape compiles twice — the star double-compile
        # the PR-9 flight recorder surfaced.  run() device_puts the initial
        # state onto the same shardings, closing the loop: one layout in,
        # the same layout out, one compilation per chunk shape.
        out_sh = getattr(engine, "state_shardings", None)
        self._jit = jax.jit(run_chunk, static_argnums=1,
                            donate_argnums=(0,) if donate else (),
                            **({"out_shardings": (out_sh, None)}
                               if out_sh is not None else {}))

    def cache_size(self):
        """Number of distinct compilations so far (one per chunk shape)."""
        try:
            return self._jit._cache_size()
        except AttributeError:      # pragma: no cover — very old/new jax
            return None

    def run(self, state, n: int):
        """Run ``n`` rounds; returns (state, metrics) with every metric (and
        the per-round CommLedger) stacked over a leading (n,) round dim.
        ``n <= 0`` is a no-op returning ``(state, None)``."""
        if n <= 0:
            return state, None
        shardings = getattr(self.engine, "state_shardings", None)
        if shardings is not None:
            # Pre-commit the input layout on the mesh paths.  init_fn's
            # state carries default device placement; the first chunk
            # compiles for that layout, but its donated OUTPUT carries the
            # program's committed NamedShardings — so the second chunk saw
            # a different input layout and recompiled the identical chunk
            # shape (the star double-compile the PR-9 flight recorder
            # surfaced).  device_put here is a no-op for already-committed
            # state, and makes chunk 1 compile against the same layout
            # every later chunk feeds back in.
            state = jax.device_put(state, shardings)
        chunks = []
        done = 0
        while done < n:
            k = min(self.chunk, n - done)
            if self.tracer is None:
                state, m = self._jit(state, k)
            else:
                # span kind "compile" when this chunk shape triggered a fresh
                # compilation (jit compiles lazily, so the span necessarily
                # includes the first execution too); "chunk" for cache hits.
                # block_until_ready keeps the wall-clock honest under async
                # dispatch — tracing opts into that sync cost.
                before = self.cache_size()
                with self.tracer.span("chunk", rounds=k) as sp:
                    state, m = self._jit(state, k)
                    jax.block_until_ready(m)
                    if before is not None and \
                            (self.cache_size() or 0) > before:
                        sp["kind"] = "compile"
            chunks.append(m)
            done += k
        if len(chunks) == 1:
            return state, chunks[0]
        metrics = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *chunks)
        return state, metrics


def run_rounds(engine: RoundEngine, state, data_fn, n: int, chunk: int = 8,
               metrics_fn=None, donate: bool = True, eval_every=None,
               tracer=None):
    """Run ``n`` FL rounds, ``chunk`` rounds per compiled scan.

    ``data_fn(round_idx) -> batch`` must be traceable (e.g. sampling from
    ``repro.data.synthetic`` with ``jax.random.fold_in(key, round_idx)``);
    it is called inside the scan body. Returns ``(final_state, metrics)``
    where every metric leaf is stacked over a leading (n,) round dim.
    ``eval_every`` (default ``FLConfig.eval_every`` via the engine) sets the
    ``metrics_fn`` cadence — see :class:`RoundRunner`.  ``tracer`` (a
    ``repro.obs.trace.Tracer``) records per-chunk compile/execute spans and
    turns on the opt-in ``jax.profiler`` hook around the whole run."""
    runner = RoundRunner(engine, data_fn, chunk=chunk, metrics_fn=metrics_fn,
                         donate=donate, eval_every=eval_every, tracer=tracer)
    if tracer is None:
        return runner.run(state, n)
    with tracer.profile():
        return runner.run(state, n)
