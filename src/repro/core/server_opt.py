"""Server-side optimizers applied to the aggregated client delta.

``fedavg`` (plain averaging) is the paper's baseline [6]; the adaptive family
(FedAvgM / FedAdam / FedYogi — Reddi et al., "Adaptive Federated
Optimization", 2020) is included as a beyond-paper extension: it often buys
the same accuracy in fewer rounds, which *is* a communication saving — the
survey's objective by other means.

All functions treat ``delta`` = weighted-mean client improvement
(p_local_final − p_global), i.e. a pseudo-gradient of −delta.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import FLConfig


def state_keys(name: str):
    return {"fedavg": [], "fedavgm": ["m"],
            "fedadam": ["m", "v"], "fedyogi": ["m", "v"]}[name]


def init_state(name: str, params):
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if name == "fedavg":
        return {}
    if name == "fedavgm":
        return {"m": zeros()}
    if name in ("fedadam", "fedyogi"):
        return {"m": zeros(), "v": zeros()}
    raise ValueError(name)


def apply(cfg: FLConfig, params, delta, state):
    lr = cfg.server_lr
    add = lambda p, u: jax.tree.map(
        lambda a, b: (a.astype(jnp.float32) + b).astype(a.dtype), p, u)

    if cfg.server_opt == "fedavg":
        return add(params, jax.tree.map(lambda d: lr * d, delta)), state

    if cfg.server_opt == "fedavgm":
        m = jax.tree.map(lambda m_, d: cfg.server_beta1 * m_ + d, state["m"], delta)
        return add(params, jax.tree.map(lambda m_: lr * m_, m)), {"m": m}

    b1, b2, eps = cfg.server_beta1, cfg.server_beta2, cfg.server_eps
    m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * d, state["m"], delta)
    if cfg.server_opt == "fedadam":
        v = jax.tree.map(lambda v_, d: b2 * v_ + (1 - b2) * d * d,
                         state["v"], delta)
    else:  # fedyogi
        v = jax.tree.map(
            lambda v_, d: v_ - (1 - b2) * d * d * jnp.sign(v_ - d * d),
            state["v"], delta)
    upd = jax.tree.map(lambda m_, v_: lr * m_ / (jnp.sqrt(v_) + eps), m, v)
    return add(params, upd), {"m": m, "v": v}
