"""Server-side optimizers applied to the aggregated client delta.

``fedavg`` (plain averaging) is the paper's baseline [6]; the adaptive family
(FedAvgM / FedAdam / FedYogi — Reddi et al., "Adaptive Federated
Optimization", 2020) is included as a beyond-paper extension: it often buys
the same accuracy in fewer rounds, which *is* a communication saving — the
survey's objective by other means (EXPERIMENTS.md §Async carries the
measured time-to-target rows these optimizers feed into).

All functions treat ``delta`` = weighted-mean client improvement
(p_local_final − p_global), i.e. a pseudo-gradient of −delta.

**Staleness awareness** (DESIGN.md §8): on the asynchronous topology the
aggregated delta is built from a FedBuff buffer whose contributions are
``tau`` server versions old on average.  A stale pseudo-gradient is a noisy
estimate of the *current* loss surface, so feeding it into the adaptive
moments at full strength lets a single ancient flush steer ``m``/``v`` for
many rounds.  :func:`apply` therefore scales the **moment innovations** by

    s = (1 + tau)^(-staleness_alpha)          (same decay as FedAsync)

    m <- b1 * m + (1 - b1) * s * delta
    v <- b2 * v + (1 - b2) * s * delta^2            (FedAdam)
    v <- v - (1 - b2) * s * delta^2 * sign(v - delta^2)   (FedYogi)
    m <- b1 * m + s * delta                          (FedAvgM)

while the parameter update keeps its usual form.  Synchronous engines pass
``staleness=None`` (tau = 0, s = 1 — the classical FedOpt update, byte- and
graph-identical to the pre-staleness implementation); the AsyncEngine
passes the flushed buffer's mean staleness (``core.async_engine``, flush
hop).  ``(1 + 0)^(-alpha) == 1.0`` exactly in IEEE arithmetic, which is
what keeps the degenerate async == sync contract bit-exact with FedAdam as
the server optimizer (tests/test_async.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import FLConfig


def state_keys(name: str):
    return {"fedavg": [], "fedavgm": ["m"],
            "fedadam": ["m", "v"], "fedyogi": ["m", "v"]}[name]


def init_state(name: str, params):
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if name == "fedavg":
        return {}
    if name == "fedavgm":
        return {"m": zeros()}
    if name in ("fedadam", "fedyogi"):
        return {"m": zeros(), "v": zeros()}
    raise ValueError(name)


def staleness_scale(cfg: FLConfig, staleness, alpha=None) -> jax.Array:
    """The moment-innovation scale s = (1 + tau)^(-alpha).  ``alpha``
    defaults to ``cfg.staleness_alpha``; the AsyncEngine passes its
    *resolved* alpha (explicit ``Topology.async_`` fields override the
    FLConfig fallback) so the moment scale always matches the FedAsync
    aggregation weights."""
    tau = jnp.asarray(staleness, jnp.float32)
    a = cfg.staleness_alpha if alpha is None else alpha
    return (1.0 + tau) ** jnp.float32(-a)


def apply(cfg: FLConfig, params, delta, state, staleness=None,
          staleness_alpha=None):
    """One server step: ``params + f(delta)`` per ``cfg.server_opt``.

    ``staleness`` (optional traced f32 scalar) is the mean staleness tau of
    the aggregated delta — the AsyncEngine passes its flushed buffer's mean
    at every flush; synchronous callers omit it (tau = 0).  It scales the
    adaptive moment innovations by ``(1 + tau)^(-alpha)`` (module
    docstring; DESIGN.md §8) and never touches plain ``fedavg``.
    ``staleness_alpha`` overrides ``cfg.staleness_alpha`` (the AsyncEngine's
    resolved Topology-level knob).
    """
    lr = cfg.server_lr
    add = lambda p, u: jax.tree.map(
        lambda a, b: (a.astype(jnp.float32) + b).astype(a.dtype), p, u)

    if cfg.server_opt == "fedavg":
        return add(params, jax.tree.map(lambda d: lr * d, delta)), state

    # staleness-scaled innovation (identity when staleness is omitted —
    # the synchronous graph is unchanged)
    if staleness is None:
        _s = lambda x: x
    else:
        s = staleness_scale(cfg, staleness, staleness_alpha)
        _s = lambda x: s * x

    if cfg.server_opt == "fedavgm":
        m = jax.tree.map(lambda m_, d: cfg.server_beta1 * m_ + _s(d),
                         state["m"], delta)
        return add(params, jax.tree.map(lambda m_: lr * m_, m)), {"m": m}

    b1, b2, eps = cfg.server_beta1, cfg.server_beta2, cfg.server_eps
    m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * _s(d),
                     state["m"], delta)
    if cfg.server_opt == "fedadam":
        v = jax.tree.map(lambda v_, d: b2 * v_ + (1 - b2) * _s(d * d),
                         state["v"], delta)
    else:  # fedyogi
        v = jax.tree.map(
            lambda v_, d: v_ - (1 - b2) * _s(d * d) * jnp.sign(v_ - d * d),
            state["v"], delta)
    upd = jax.tree.map(lambda m_, v_: lr * m_ / (jnp.sqrt(v_) + eps), m, v)
    return add(params, upd), {"m": m, "v": v}
