"""Hierarchical FL: client -> edge (pod) -> cloud (cross-pod) — the
``Topology.hier`` binding of the RoundEngine.

Maps Hier-Local-QSGD [73] and FedPAQ's periodic averaging [45] onto the
multi-pod mesh (DESIGN.md §1.3):

  * every round: clients aggregate *within* their pod over the ``data`` axis
    (the "edge server" hop — cheap ICI);
  * every ``sync_every`` rounds: the per-pod models additionally aggregate
    across the ``pod`` axis (the "cloud" hop — expensive DCN), with its own
    compressor (``pod_compressor``) — Hier-Local-QSGD quantises exactly this
    hop.

The edge hop runs the full uplink CommPipeline *statefully*: error-feedback
residuals / DGC momentum ride in ``FLState.comm_state`` with (G, Ce)
leading dims sharded over (pod, data) — biased pipelines keep their
correction on the edge hop, same as the star path (DESIGN.md §5).

Between cloud syncs the per-pod models *diverge* (that is the point — it is
what buys the communication reduction), so parameters and server-optimizer
state carry a leading G = n_pods dim sharded over ``pod``. Rather than a
``lax.cond`` around a collective, the factory exposes **two** step programs
(edge-only and edge+cloud) and lets per-round drivers alternate — the
deployment-realistic schedule, and it keeps each HLO's collective set honest
for the roofline. (The engine's scan driver ``run_rounds`` instead uses the
engine's cond-based ``round_fn``, which folds the alternation into one
compiled program.)
"""
from __future__ import annotations

import dataclasses
from typing import Any

from jax.sharding import Mesh

from repro.core.engine import Topology, make_round_engine
from repro.core.types import FLConfig
from repro.models.model import Model

PyTree = Any


@dataclasses.dataclass
class HierFLStep:
    init_fn: Any
    step_edge: Any          # every round
    step_cloud: Any         # every sync_every rounds (edge + pod sync)
    state_shardings: Any
    n_pods: int
    clients_per_pod: int
    terms: dict
    engine: Any = None      # the underlying RoundEngine (for run_rounds)


def make_hier_fl_train_step(model: Model, fl: FLConfig, mesh: Mesh,
                            chunk: int = 512) -> HierFLStep:
    engine = make_round_engine(model, fl, Topology.hier(fl.sync_every),
                               mesh=mesh, chunk=chunk)
    return HierFLStep(
        init_fn=engine.init_fn,
        step_edge=engine.programs["edge"],
        step_cloud=engine.programs["cloud"],
        state_shardings=engine.state_shardings,
        n_pods=engine.aux["n_pods"],
        clients_per_pod=engine.aux["clients_per_pod"],
        terms=engine.terms,
        engine=engine,
    )
