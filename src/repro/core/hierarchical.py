"""Hierarchical FL: client -> edge (pod) -> cloud (cross-pod).

Maps Hier-Local-QSGD [73] and FedPAQ's periodic averaging [45] onto the
multi-pod mesh (DESIGN.md §1.3):

  * every round: clients aggregate *within* their pod over the ``data`` axis
    (the "edge server" hop — cheap ICI);
  * every ``sync_every`` rounds: the per-pod models additionally aggregate
    across the ``pod`` axis (the "cloud" hop — expensive DCN), with its own
    compressor (``pod_compressor``) — Hier-Local-QSGD quantises exactly this
    hop.

Between cloud syncs the per-pod models *diverge* (that is the point — it is
what buys the communication reduction), so parameters and server-optimizer
state carry a leading G = n_pods dim sharded over ``pod``. Rather than a
``lax.cond`` around a collective, we compile **two** step programs (edge-only
and edge+cloud) and let the driver alternate — the deployment-realistic
schedule, and it keeps each HLO's collective set honest for the roofline.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compress.api import make_compressor
from repro.core import server_opt
from repro.core.types import CommLedger, FLConfig
from repro.models import sharding as shd
from repro.models.model import Model

from repro.core.compat import shard_map
PyTree = Any


@dataclasses.dataclass
class HierFLStep:
    init_fn: Any
    step_edge: Any          # every round
    step_cloud: Any         # every sync_every rounds (edge + pod sync)
    state_shardings: Any
    n_pods: int
    clients_per_pod: int
    terms: dict


def make_hier_fl_train_step(model: Model, fl: FLConfig, mesh: Mesh,
                            chunk: int = 512) -> HierFLStep:
    assert "pod" in mesh.axis_names, "hierarchical FL needs a pod axis"
    cfg = model.cfg
    sizes = dict(mesh.shape)
    G, Ce = sizes["pod"], sizes["data"]

    pspecs = shd.tree_specs(model.abstract_params(), model.logical_axes(),
                            mesh, cfg.fsdp)
    gspecs = shd.with_prefix(pspecs, "pod")                  # (G, ...) params
    dspecs = shd.with_prefix(pspecs, "pod", "data")          # (G, Ce, ...)

    up = make_compressor(fl.uplink_compressor, fraction=fl.topk_fraction,
                         block=fl.qsgd_block)
    pod_comp = make_compressor(fl.pod_compressor, block=fl.qsgd_block)

    nparams = [int(np.prod(d.shape)) for d in
               jax.tree.leaves(model.defs, is_leaf=lambda x: hasattr(x, "logical"))]
    terms = {
        "edge_wire": sum(up.wire_bits(n) for n in nparams) / 8.0 * Ce * G,
        "cloud_wire": sum(pod_comp.wire_bits(n) for n in nparams) / 8.0 * G,
        "dense": sum(32.0 * n for n in nparams) / 8.0 * Ce * G,
    }

    # ------------------------------------------------------------------ agg
    def _agg_edge(deltas, weights, rng):
        """Edge hop: within-pod aggregation. deltas (G, Ce, ...), weights
        (G, Ce) replicated -> per-pod mean delta (G, ...)."""
        def body(dtree, w):
            gi = jax.lax.axis_index("pod")
            ci = jax.lax.axis_index("data")
            out = []
            for li, leaf in enumerate(jax.tree.leaves(dtree)):
                flat = leaf.reshape(-1).astype(jnp.float32)
                r = jax.random.fold_in(jax.random.fold_in(rng, li),
                                       gi * Ce + ci)
                if up.is_identity:
                    contrib = w[gi, ci] * flat
                    edge = jax.lax.psum(contrib, "data") / \
                        jnp.maximum(jax.lax.psum(w[gi, ci], "data"), 1e-9)
                else:
                    payload, _ = up.encode(up.init(flat.shape), r, flat)
                    gath = jax.lax.all_gather(payload, "data")
                    dec = jax.vmap(lambda q: up.decode(q, flat.shape[0]))(gath)
                    wrow = w[gi]
                    edge = (wrow[:, None] * dec).sum(0) / \
                        jnp.maximum(wrow.sum(), 1e-9)
                out.append(edge.reshape((1,) + leaf.shape[2:]).astype(leaf.dtype))
            return jax.tree.unflatten(jax.tree.structure(dtree), out)

        return shard_map(body, mesh=mesh, in_specs=(dspecs, P()),
                         out_specs=gspecs, check_vma=False)(deltas, weights)

    def _sync_models(params, rng):
        """Cloud hop: periodic *model* averaging across pods (FedPAQ /
        Hier-Local-QSGD), quantised with ``pod_compressor``. All pods leave
        with the identical synced model."""
        def body(ptree):
            out = []
            for li, leaf in enumerate(jax.tree.leaves(ptree)):
                flat = leaf.reshape(-1).astype(jnp.float32)
                r = jax.random.fold_in(rng, li)
                if pod_comp.is_identity:
                    synced = jax.lax.pmean(flat, "pod")
                else:
                    pay, _ = pod_comp.encode(
                        pod_comp.init(flat.shape),
                        jax.random.fold_in(r, jax.lax.axis_index("pod")), flat)
                    gath = jax.lax.all_gather(pay, "pod")
                    dec = jax.vmap(lambda q: pod_comp.decode(
                        q, flat.shape[0]))(gath)
                    synced = dec.mean(0)
                out.append(synced.reshape(leaf.shape).astype(leaf.dtype))
            return jax.tree.unflatten(jax.tree.structure(ptree), out)

        return shard_map(body, mesh=mesh, in_specs=(gspecs,),
                         out_specs=gspecs, check_vma=False)(params)

    # ------------------------------------------------------------------ step
    def _make_step(cloud: bool):
        def step_fn(state, batch):
            params, sos, rng, rnd = state
            r_loc, r_up, r_next = jax.random.split(rng, 3)

            def client_upd(params_g, batch_c, r):
                lr = fl.local_lr
                loss_fn = lambda p: model.loss(p, batch_c, chunk=chunk)[0]

                def one(p_c, _):
                    loss, g = jax.value_and_grad(loss_fn)(p_c)
                    p_c = jax.tree.map(
                        lambda a, g_: (a.astype(jnp.float32)
                                       - lr * g_.astype(jnp.float32)
                                       ).astype(a.dtype), p_c, g)
                    return p_c, loss
                p_fin, losses = jax.lax.scan(one, params_g, None,
                                             length=fl.local_steps)
                delta = jax.tree.map(
                    lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                    p_fin, params_g)
                return delta, losses.mean()

            rngs = jax.random.split(r_loc, G * Ce).reshape(G, Ce, -1)
            model_batch = {k: v for k, v in batch.items() if k != "sizes"}
            deltas, losses = jax.vmap(lambda pg, bg, rg: jax.vmap(
                lambda bc, rc: client_upd(pg, bc, rc))(bg, rg))(
                params, model_batch, rngs)

            weights = batch.get("sizes", jnp.ones((G, Ce), jnp.float32))
            agg = _agg_edge(deltas, weights, r_up)

            # per-pod server update (vmap-free: tree ops broadcast over G)
            new_params, new_sos = server_opt.apply(fl, params, agg, sos)
            if cloud:   # periodic model averaging across pods
                new_params = _sync_models(new_params,
                                          jax.random.fold_in(r_up, 99))
            wire = terms["edge_wire"] + (terms["cloud_wire"] if cloud else 0.0)
            metrics = {
                "loss": losses.mean(),
                "ledger": CommLedger(
                    uplink_wire=jnp.float32(wire),
                    uplink_entropy=jnp.float32(wire),
                    downlink_wire=jnp.float32(0.0),
                    uplink_dense=jnp.float32(terms["dense"]),
                    downlink_dense=jnp.float32(0.0)),
                "pod_divergence": _pod_divergence(new_params),
            }
            return (new_params, new_sos, r_next, rnd + 1), metrics
        return step_fn

    def _pod_divergence(params):
        """Mean squared distance of per-pod models from their mean — the
        periodic-averaging 'staleness' the cloud hop resets.

        Probed on a fixed small slice of the largest leaf: an exact
        full-parameter version costs a full-model pod all-reduce per round
        (measured: +16.4 GB/dev on qwen32b — more than the FL wire itself),
        so the metric must not dominate the step it measures."""
        leaves = sorted(jax.tree.leaves(params), key=lambda l: -l.size)
        probe = leaves[0].reshape(leaves[0].shape[0], -1)[:, :4096]
        probe = probe.astype(jnp.float32)
        return jnp.mean((probe - probe.mean(0, keepdims=True)) ** 2)

    def init_fn(rng):
        params = model.init(rng)
        params = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (G,) + p.shape), params)
        sos = server_opt.init_state(fl.server_opt, params)
        return (params, sos, jax.random.PRNGKey(fl.seed), jnp.zeros((), jnp.int32))

    state_specs = (gspecs, {k: gspecs for k in server_opt.state_keys(fl.server_opt)},
                   P(), P())
    state_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs,
                                   is_leaf=lambda x: isinstance(x, P))

    return HierFLStep(
        init_fn=init_fn,
        step_edge=_make_step(cloud=False),
        step_cloud=_make_step(cloud=True),
        state_shardings=state_shardings,
        n_pods=G, clients_per_pod=Ce, terms=terms,
    )
