"""Compressed FL aggregation — the wire.

This is where the survey's subject physically happens on the TPU mesh: the
per-client update pytree crosses the ICI/DCN links. The aggregation runs in a
``shard_map`` over the client mesh axes so that **the compressed payload is
the collective operand** — an ``all_gather`` of int8/ternary/top-k arrays, not
an f32 all-reduce. The dry-run's HLO collective-byte count therefore measures
exactly what each compressor claims to save.

Baseline (Identity) uses a weighted ``psum`` instead (f32 all-reduce — the
FedAvg wire format), so baseline vs compressed is an apples-to-apples HLO
diff.

Error feedback (biased compressors): the residual e_i lives with its client
(leading C dim on the residual tree); compress(delta + e_i) is gathered, and
e_i' = (delta + e_i) − Q(delta + e_i) never crosses the network.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

shard_map = jax.shard_map

from repro.compress.api import Compressor, Identity

PyTree = Any


def client_axes(mesh: Mesh, client_axis: str) -> tuple:
    if client_axis == "pod":
        return ("pod",) if "pod" in mesh.axis_names else ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def client_index(axes: Sequence[str], mesh: Mesh):
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * dict(mesh.shape)[a] + jax.lax.axis_index(a)
    return idx


def make_aggregator(mesh: Mesh, param_specs: PyTree, comp: Compressor,
                    client_axis: str = "data"):
    """Returns ``aggregate(deltas, weights, rng, residual) ->
    (agg, new_residual)`` where deltas/residual have a leading global-client
    dim sharded over the client mesh axes, and ``agg`` has param shapes.

    ``weights`` (C,) is replicated; zero-weight clients' payloads still cross
    the wire (they were *selected out* — the ledger accounts only selected
    clients' bytes, see federated.py)."""
    axes = client_axes(mesh, client_axis)
    C = int(np.prod([dict(mesh.shape)[a] for a in axes])) if axes else 1
    leaves_specs = jax.tree.leaves(param_specs, is_leaf=lambda s: isinstance(s, P))
    treedef = jax.tree.structure(param_specs, is_leaf=lambda s: isinstance(s, P))

    in_delta_specs = jax.tree.map(lambda s: P(axes if axes else None, *s),
                                  param_specs, is_leaf=lambda s: isinstance(s, P))
    out_agg_specs = param_specs
    ef = comp.biased

    def body(deltas, weights, rng, residual):
        idx = client_index(axes, mesh) if axes else jnp.zeros((), jnp.int32)
        wsum = jnp.maximum(weights.sum(), 1e-9)
        flat_leaves = jax.tree.leaves(deltas)
        res_leaves = jax.tree.leaves(residual) if ef else [None] * len(flat_leaves)
        agg_out, res_out = [], []
        for li, (leaf, res) in enumerate(zip(flat_leaves, res_leaves)):
            local_shape = leaf.shape[1:]          # squeeze local client dim (1)
            flat = leaf.reshape(-1).astype(jnp.float32)
            if ef:
                flat = flat + res.reshape(-1).astype(jnp.float32)
            n = flat.shape[0]
            r = jax.random.fold_in(jax.random.fold_in(rng, li), idx)
            if isinstance(comp, Identity):
                # psum in the delta's own dtype — bf16 deltas (beyond-paper
                # §Perf lever) halve the wire; f32 is the faithful baseline
                contrib = (weights[idx] * flat).astype(leaf.dtype)
                tot = jax.lax.psum(contrib, axes) if axes else contrib
                agg = tot.astype(jnp.float32) / wsum
                dec_own = flat
            else:
                payload = comp.compress(r, flat)
                if axes:
                    # one fused leading dim of size C, ordered to match
                    # client_index (verified: pod-major, data-minor)
                    gathered = jax.lax.all_gather(payload, axes, tiled=False)
                else:
                    gathered = jax.tree.map(lambda a: a[None], payload)
                dec = jax.vmap(lambda pl_: comp.decompress(pl_, n))(gathered)
                agg = (weights[:, None] * dec).sum(0) / wsum
                dec_own = dec[idx]
            agg_out.append(agg.reshape(local_shape).astype(leaf.dtype))
            if ef:
                res_out.append((flat - dec_own).reshape((1,) + local_shape))
        agg_tree = jax.tree.unflatten(jax.tree.structure(deltas), agg_out)
        res_tree = (jax.tree.unflatten(jax.tree.structure(deltas), res_out)
                    if ef else None)
        return agg_tree, res_tree

    in_specs = (in_delta_specs, P(), P(),
                in_delta_specs if ef else None)
    out_specs = (out_agg_specs, in_delta_specs if ef else None)

    def aggregate(deltas, weights, rng, residual=None):
        # shard_map can't take None pytrees for the residual slot when ef is
        # off; close over it instead.
        if ef:
            fn = shard_map(
                lambda d, w, r, e: body(d, w, r, e),
                mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False)
            agg, new_res = fn(deltas, weights, rng, residual)
            return agg, new_res
        fn = shard_map(
            lambda d, w, r: body(d, w, r, None)[0],
            mesh=mesh, in_specs=in_specs[:3], out_specs=out_specs[0],
            check_vma=False)
        agg = fn(deltas, weights, rng)
        return agg, None

    return aggregate
