"""Compressed FL aggregation — the wire.

This is where the survey's subject physically happens on the TPU mesh: the
per-client update pytree crosses the ICI/DCN links. The aggregation runs in a
``shard_map`` over the client mesh axes so that **the encoded payload is the
collective operand** — an ``all_gather`` of int8/ternary/top-k arrays, not an
f32 all-reduce. The dry-run's HLO collective-byte count therefore measures
exactly what each pipeline claims to save.

Baseline (Identity) uses a weighted ``psum`` instead (f32 all-reduce — the
FedAvg wire format), so baseline vs compressed is an apples-to-apples HLO
diff.

Pipeline state (error-feedback residuals, DGC momentum, ...): the pipeline
owns it (``CommTransform.init/encode``), the trainer merely threads it. Each
client's state shard lives with its client — a leading C dim over the client
mesh axes — and never crosses the network: ``encode`` consumes and returns it
inside the shard_map body, and only the payload is gathered.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map
from repro.compress.api import CommTransform
from repro.compress.secure_agg import MASK_TAG, has_mask_ctx, inject_mask_ctx

PyTree = Any


def client_axes(mesh: Mesh, client_axis: str) -> tuple:
    if client_axis == "pod":
        return ("pod",) if "pod" in mesh.axis_names else ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def client_index(axes: Sequence[str], mesh: Mesh):
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * dict(mesh.shape)[a] + jax.lax.axis_index(a)
    return idx


# ---------------------------------------------------------------------------
# Pipeline comm-state plumbing (shared by federated.py / simulate.py)
# ---------------------------------------------------------------------------

def comm_state_template(pipe: CommTransform, params: PyTree):
    """Abstract per-leaf pipeline states: a tuple over param leaves of
    ``jax.eval_shape(pipe.init, leaf.shape)`` pytrees."""
    return tuple(jax.eval_shape(functools.partial(pipe.init, tuple(p.shape)))
                 for p in jax.tree.leaves(params))


def comm_state_init(pipe: CommTransform, params: PyTree, lead):
    """Concrete zero state with leading client dim(s) ``lead`` on every array
    (the init contract: pipeline state starts at zero). ``lead`` is the
    global client count C, or a tuple of leading dims — e.g. ``(G, Ce)`` for
    the hierarchical (pod, data) client grid."""
    lead = (lead,) if isinstance(lead, int) else tuple(lead)
    return tuple(
        jax.tree.map(lambda a: jnp.zeros(lead + a.shape, a.dtype), tmpl)
        for tmpl in comm_state_template(pipe, params))


def comm_state_specs(pipe: CommTransform, params: PyTree, param_specs: PyTree,
                     axes: tuple, separate: bool = False):
    """PartitionSpecs for the comm state: client dim(s) over the client axes;
    leaf-shaped state arrays (residuals, momenta) additionally inherit the
    parameter's own sharding, anything else is replicated.

    ``separate=False`` (star/gossip): ONE fused leading dim sharded over all
    ``axes``. ``separate=True`` (hier): one leading dim per axis — e.g.
    ``("pod", "data")`` -> a (G, Ce) client grid."""
    p_leaves = jax.tree.leaves(params)
    s_leaves = jax.tree.leaves(param_specs, is_leaf=lambda s: isinstance(s, P))
    lead = tuple(axes) if separate else ((axes if axes else None),)
    out = []
    for pl, sl in zip(p_leaves, s_leaves):
        tmpl = jax.eval_shape(functools.partial(pipe.init, tuple(pl.shape)))
        out.append(jax.tree.map(
            lambda a, pl=pl, sl=sl: (
                P(*lead, *sl) if tuple(a.shape) == tuple(pl.shape)
                else P(*lead, *([None] * a.ndim))), tmpl))
    return tuple(out)


# ---------------------------------------------------------------------------
# The aggregator
# ---------------------------------------------------------------------------

def make_aggregator(mesh: Mesh, param_specs: PyTree, pipe: CommTransform,
                    client_axis: str = "data", abstract_params: PyTree = None):
    """Returns ``aggregate(deltas, weights, rng, comm_state) ->
    (agg, new_comm_state)`` where deltas have a leading global-client dim
    sharded over the client mesh axes, ``comm_state`` is the pipeline state
    from :func:`comm_state_init` (or None for stateless pipelines), and
    ``agg`` has param shapes.

    ``weights`` (C,) is replicated; zero-weight clients' payloads still cross
    the wire (they were *selected out* — the ledger accounts only selected
    clients' bytes, see federated.py)."""
    axes = client_axes(mesh, client_axis)
    C = int(np.prod([dict(mesh.shape)[a] for a in axes])) if axes else 1

    in_delta_specs = jax.tree.map(lambda s: P(axes if axes else None, *s),
                                  param_specs, is_leaf=lambda s: isinstance(s, P))
    out_agg_specs = param_specs
    stateful = pipe.stateful
    if stateful and abstract_params is None:
        raise ValueError("stateful pipelines need abstract_params to build "
                         "comm-state sharding specs")
    state_specs = (comm_state_specs(pipe, abstract_params, param_specs, axes)
                   if stateful else None)

    def body(deltas, weights, rng, comm_state):
        idx = client_index(axes, mesh) if axes else jnp.zeros((), jnp.int32)
        wsum = jnp.maximum(weights.sum(), 1e-9)
        flat_leaves = jax.tree.leaves(deltas)
        agg_out, st_out = [], []
        for li, leaf in enumerate(flat_leaves):
            local_shape = leaf.shape[1:]          # squeeze local client dim (1)
            flat = leaf.reshape(-1).astype(jnp.float32)
            n = flat.shape[0]
            r = jax.random.fold_in(jax.random.fold_in(rng, li), idx)
            if pipe.is_identity:
                # psum in the delta's own dtype — bf16 deltas (beyond-paper
                # §Perf lever) halve the wire; f32 is the faithful baseline
                contrib = (weights[idx] * flat).astype(leaf.dtype)
                tot = jax.lax.psum(contrib, axes) if axes else contrib
                agg = tot.astype(jnp.float32) / wsum
            else:
                st = (jax.tree.map(lambda a: a[0], comm_state[li])
                      if stateful else pipe.init((n,)))
                if has_mask_ctx(pipe):
                    # secagg context for the star wire: the mask ring spans
                    # the gathered client axis — idx is this device's
                    # client_index, cohort the full C the all_gather sees
                    mkey = jax.random.fold_in(
                        jax.random.fold_in(rng, MASK_TAG), li)
                    st = inject_mask_ctx(st, mkey, idx, C)
                payload, new_st = pipe.encode(st, r, flat)
                if axes:
                    # one fused leading dim of size C, ordered to match
                    # client_index (verified: pod-major, data-minor)
                    gathered = jax.lax.all_gather(payload, axes, tiled=False)
                else:
                    gathered = jax.tree.map(lambda a: a[None], payload)
                dec = jax.vmap(lambda pl_: pipe.decode(pl_, n))(gathered)
                agg = (weights[:, None] * dec).sum(0) / wsum
                if stateful:
                    st_out.append(jax.tree.map(lambda a: a[None], new_st))
            agg_out.append(agg.reshape(local_shape).astype(leaf.dtype))
        agg_tree = jax.tree.unflatten(jax.tree.structure(deltas), agg_out)
        return agg_tree, (tuple(st_out) if stateful else None)

    in_specs = (in_delta_specs, P(), P(), state_specs)
    out_specs = (out_agg_specs, state_specs)

    def aggregate(deltas, weights, rng, comm_state=None):
        # shard_map can't take None pytrees for the state slot when the
        # pipeline is stateless; close over it instead.
        if stateful:
            fn = shard_map(
                lambda d, w, r, s: body(d, w, r, s),
                mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False)
            return fn(deltas, weights, rng, comm_state)
        fn = shard_map(
            lambda d, w, r: body(d, w, r, None)[0],
            mesh=mesh, in_specs=in_specs[:3], out_specs=out_specs[0],
            check_vma=False)
        agg = fn(deltas, weights, rng)
        return agg, None

    return aggregate
