"""FL + Hierarchical Clustering (Briggs et al. [43], survey §III.B.1).

After ``warmup`` FedAvg rounds, clients are clustered by the *similarity of
their local updates* (pairwise distance over flattened deltas —
agglomerative, complete linkage, distance threshold), and each cluster
continues training its own model. On clustered non-iid data this both
improves per-client accuracy and cuts the rounds-to-target — the paper's
claimed communication saving.

Our synthetic federated corpus (`repro.data.synthetic`) has ground-truth
generator clusters (`num_clusters`), so the reproduction can measure cluster
*recovery* directly (`adjusted_match`), not just loss.
"""
from __future__ import annotations

import numpy as np


def pairwise_delta_distance(deltas_flat: np.ndarray, metric="cosine"):
    """deltas_flat: (C, n) per-client update matrix -> (C, C) distances."""
    X = np.asarray(deltas_flat, dtype=np.float64)
    if metric == "cosine":
        norms = np.linalg.norm(X, axis=1, keepdims=True) + 1e-12
        S = (X / norms) @ (X / norms).T
        return 1.0 - S
    if metric == "l1":                       # Manhattan — the metric [43]
        return np.abs(X[:, None, :] - X[None, :, :]).sum(-1)  # compares via
    raise ValueError(metric)


def agglomerate(D: np.ndarray, threshold: float):
    """Complete-linkage agglomerative clustering with a distance threshold.
    Returns integer labels (C,). Pure numpy (no sklearn in this container)."""
    C = D.shape[0]
    clusters = [[i] for i in range(C)]

    def complete(a, b):
        return max(D[i, j] for i in a for j in b)

    while len(clusters) > 1:
        best, bi, bj = None, -1, -1
        for i in range(len(clusters)):
            for j in range(i + 1, len(clusters)):
                d = complete(clusters[i], clusters[j])
                if best is None or d < best:
                    best, bi, bj = d, i, j
        if best is None or best > threshold:
            break
        clusters[bi] = clusters[bi] + clusters[bj]
        del clusters[bj]
    labels = np.zeros(C, dtype=int)
    for k, cl in enumerate(clusters):
        for i in cl:
            labels[i] = k
    return labels


def adjusted_match(labels: np.ndarray, truth: np.ndarray) -> float:
    """Fraction of client pairs whose same/different-cluster relation matches
    the ground truth (pairwise Rand-style score, 1.0 = exact recovery)."""
    labels, truth = np.asarray(labels), np.asarray(truth)
    C = len(labels)
    agree = total = 0
    for i in range(C):
        for j in range(i + 1, C):
            agree += (labels[i] == labels[j]) == (truth[i] == truth[j])
            total += 1
    return agree / max(total, 1)
