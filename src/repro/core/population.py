"""ClientPopulation — the client axis at survey scale.

Every engine before this subsystem materialized the whole client axis:
``(C,)`` latency/size/availability vectors, O(C x model) EF residuals in
``FLState.comm_state``, and a data batch per client per round.  That caps
C in the low thousands, while the survey's production regime is 10^5–10^6
devices with a **sub-percent cohort** actually participating per round.

``ClientPopulation`` inverts the layout: the population is a set of
*deterministic per-id generators* (data, sizes, resources and availability
all derive from ``fold_in(key, client_id)``), and each round materializes
only a fixed-shape cohort slice of ``cohort`` ids.  Per-client pipeline
state lives in a bounded :class:`~repro.compress.residual_store
.ResidualStore` (gather on dispatch, scatter on commit) instead of dense
``comm_state`` rows, so memory is flat in ``n_clients``.

Degenerate contract: ``cohort == n_clients`` makes ``cohort_ids`` the
identity ``arange(C)`` and (with ``capacity >= n_clients``) the store a
value-identity — the population path is then bit-exact vs the dense
engines, which is how tests/test_population.py pins it.

Cohort sampling is pure in ``(seed, round_idx)`` — the engine and the data
pipeline each call :meth:`cohort_ids` independently and must agree, the
same determinism trick the rng-schedule hops use.  Two samplers:

  * ``"shuffle"`` — a full ``jax.random.permutation`` slice; exact uniform
    sampling without replacement, but O(C log C) per round, so it is the
    default only up to 65536 clients.
  * ``"stride"`` — an affine lattice ``(offset + s * arange(M)) % C`` with
    ``gcd(s, C) == 1``: collision-free by construction, O(M) compute and
    memory, and the stride is drawn per round from precomputed coprimes
    near ``C / golden_ratio`` so successive cohorts decorrelate.  Strides
    are capped at ``(2^31 - 1) // M`` so ``s * arange(M)`` stays exact in
    uint32 before the mod.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.residual_store import EVICTION_POLICIES, ResidualStore
from repro.core import scenario as _scn

SAMPLERS = ("auto", "shuffle", "stride")
_SHUFFLE_LIMIT = 65536


def _coprime_strides(C: int, M: int, count: int = 64) -> np.ndarray:
    """Static table of strides coprime to C near C/phi (phi = golden ratio),
    capped so ``stride * (M - 1)`` fits in int32 — the uint32 lattice
    arithmetic then cannot alias before the final ``% C``."""
    cap = max(1, (2 ** 31 - 1) // max(M, 1))
    target = min(max(1, int(C * 0.6180339887)), cap, C - 1) if C > 1 else 1
    out = []
    for d in range(C):
        for s in (target - d, target + d):
            if 1 <= s <= min(cap, C - 1) and math.gcd(s, C) == 1:
                out.append(s)
        if len(out) >= count:
            break
    return np.unique(np.asarray(out or [1], np.int64)).astype(np.uint32)


@dataclasses.dataclass(frozen=True)
class ClientPopulation:
    """Streaming client axis: ``n_clients`` ids, ``cohort`` per round.

    ``capacity`` bounds the residual store (0 => ``min(n_clients,
    2 * cohort)``, which degenerates to exactly ``n_clients`` when
    ``cohort == n_clients``).  ``availability < 1.0`` drops each sampled
    client i.i.d. per round via a per-id fold_in draw (the selection hop
    zero-weights them); 1.0 is statically skipped so the degenerate path
    stays bit-exact.  ``scenario`` (a :class:`repro.core.scenario
    .Scenario`) replaces the i.i.d. draw with its diurnal/square trace —
    the rate stays this population's ``availability``, the trace only
    shapes *when* each client's duty lands (core.scenario owns the single
    shared mask implementation)."""
    n_clients: int
    cohort: int = 0
    capacity: int = 0
    eviction: str = "drop"
    sampler: str = "auto"
    availability: float = 1.0
    seed: int = 0
    tail_rows: int = 5
    tail_cols: int = 16384
    scenario: Optional[object] = None

    def __post_init__(self):
        if self.n_clients < 1:
            raise ValueError(f"n_clients must be >= 1; got {self.n_clients}")
        if self.cohort == 0:
            object.__setattr__(self, "cohort", self.n_clients)
        if not (1 <= self.cohort <= self.n_clients):
            raise ValueError(
                f"cohort must be in [1, n_clients={self.n_clients}]; "
                f"got {self.cohort}")
        if self.capacity == 0:
            object.__setattr__(
                self, "capacity", min(self.n_clients, 2 * self.cohort))
        if self.capacity < self.cohort:
            raise ValueError(
                f"store capacity ({self.capacity}) must be >= cohort "
                f"({self.cohort}): a round's scatter would collide")
        if self.eviction not in EVICTION_POLICIES:
            raise ValueError(f"eviction must be one of {EVICTION_POLICIES}; "
                             f"got {self.eviction!r}")
        if self.sampler not in SAMPLERS:
            raise ValueError(f"sampler must be one of {SAMPLERS}; "
                             f"got {self.sampler!r}")
        if not (0.0 < self.availability <= 1.0):
            raise ValueError(
                f"availability must be in (0, 1]; got {self.availability}")
        if self.sampler == "auto":
            object.__setattr__(
                self, "sampler",
                "shuffle" if self.n_clients <= _SHUFFLE_LIMIT else "stride")
        if self.sampler == "shuffle" and self.n_clients > _SHUFFLE_LIMIT:
            raise ValueError(
                f"sampler='shuffle' permutes all {self.n_clients} ids per "
                f"round; use 'stride' above {_SHUFFLE_LIMIT}")
        # host-side static stride table (traced code only indexes it)
        if self.sampler == "stride" and self.cohort < self.n_clients:
            object.__setattr__(self, "_strides",
                               _coprime_strides(self.n_clients, self.cohort))

    # ------------------------------------------------------------- sampling
    def _key(self, round_idx):
        return jax.random.fold_in(jax.random.PRNGKey(self.seed + 7),
                                  round_idx)

    def cohort_ids(self, round_idx):
        """(cohort,) int32 unique client ids for this round; traced-safe,
        pure in (seed, round_idx).  ``cohort == n_clients`` => arange —
        the degenerate identity the bit-exactness tests pin."""
        C, M = self.n_clients, self.cohort
        if M == C:
            return jnp.arange(C, dtype=jnp.int32)
        if self.sampler == "shuffle":
            return jax.random.permutation(
                self._key(round_idx), C)[:M].astype(jnp.int32)
        strides = jnp.asarray(self._strides)
        k_s, k_o = jax.random.split(self._key(round_idx))
        s = strides[jax.random.randint(k_s, (), 0, strides.shape[0])]
        off = jax.random.randint(
            k_o, (), 0, C, dtype=jnp.uint32
            if C > 2 ** 31 - 1 else jnp.int32).astype(jnp.uint32)
        lattice = off + s * jnp.arange(M, dtype=jnp.uint32)
        return (lattice % jnp.uint32(C)).astype(jnp.int32)

    @property
    def availability_active(self) -> bool:
        """Static gate for the mask hops: draws are needed either below
        full availability or under a time-varying scenario trace."""
        return (self.availability < 1.0
                or (self.scenario is not None and self.scenario.diurnal))

    def availability_mask(self, round_idx, ids):
        """(M,) f32 in {0,1}: per-(id, round) availability draws — i.i.d.
        Bernoulli(availability) by default, the scenario's diurnal/square
        trace when one is attached.  Delegates to the ONE shared
        implementation in ``core.scenario`` (the same function the dense
        selection hop calls), so the Bernoulli semantics cannot drift
        between the two consumers.  Callers statically skip this when
        ``availability_active`` is False."""
        return _scn.availability_mask(self.scenario, self.seed,
                                      self.availability, round_idx, ids)

    def availability_count(self, round_idx, ids):
        """() f32: how many of this round's cohort are available — the
        flight recorder's availability count (repro.obs.telemetry).  Pure
        in (seed, round, ids) like ``availability_mask`` and statically the
        full cohort when no draw is active, matching the callers' skip."""
        if not self.availability_active:
            return jnp.float32(int(ids.shape[0]))
        return self.availability_mask(round_idx, ids).sum()

    # ---------------------------------------------------------------- store
    def make_store(self, pipe, params) -> Optional[ResidualStore]:
        """ResidualStore for this population, or None for a stateless
        pipeline (no per-client rows to keep)."""
        if not getattr(pipe, "stateful", False):
            return None
        return ResidualStore(pipe, params, self.capacity,
                             eviction=self.eviction,
                             tail_rows=self.tail_rows,
                             tail_cols=self.tail_cols)
