# The paper's primary contribution as a system: federated learning with
# first-class communication efficiency (algorithms, compression-aware
# aggregation, client selection, hierarchical sync, byte ledger).
from repro.core.types import ArchConfig, ShapeConfig, FLConfig, FLState, CommLedger
