"""``make_fl_train_step`` — one jit-compiled FL round on the **star**
topology (clients on mesh axes), as a thin binding over the RoundEngine
(``repro.core.engine``):

  local updating (FedAvg E epochs / FedSGD / FedProx / SCAFFOLD)
  -> client selection (all / random / power-of-choice / multi-criteria)
  -> compressed shard_map aggregation (CommPipeline, state threaded
     through FLState.comm_state — error feedback / DGC momentum are
     wrapping transforms owned by the pipeline, not this trainer)
  -> server optimizer (FedAvg / FedAvgM / FedAdam / FedYogi)
  -> communication ledger

The hop sequence, selection/server-opt/ledger plumbing and the client
update all live in the engine — this module only binds
``Topology.star(client_axis)`` and re-exposes the legacy surface.

Batch layout (client-major; ``C`` = number of FL clients on the mesh):
  tokens/labels/mask : (C, B_local, S)
  sizes              : (C,)      client dataset sizes (FedAvg weighting)
  resources          : (C, 4)    simulated device profile (FedMCCS)
  [+ patches / frontend for vlm / encdec archs]
"""
from __future__ import annotations

import dataclasses
from typing import Any

from jax.sharding import Mesh

from repro.core.engine import (Topology, _client_update,  # noqa: F401
                               ledger_terms, make_round_engine,
                               uplink_pipeline)
from repro.core.types import FLConfig
from repro.models.model import Model

PyTree = Any


@dataclasses.dataclass
class FLTrainStep:
    init_fn: Any            # (rng, batch_like) -> FLState (sharded)
    step_fn: Any            # (state, batch) -> (state, metrics)  [jitted]
    state_shardings: Any
    batch_sharding_fn: Any  # batch pytree -> shardings
    n_clients: int
    terms: dict
    engine: Any = None      # the underlying RoundEngine (for run_rounds)


def make_fl_train_step(model: Model, fl: FLConfig, mesh: Mesh,
                       chunk: int = 512) -> FLTrainStep:
    engine = make_round_engine(model, fl, Topology.star(model.cfg.client_axis),
                               mesh=mesh, chunk=chunk)
    return FLTrainStep(
        init_fn=engine.init_fn,
        step_fn=engine.round_fn,
        state_shardings=engine.state_shardings,
        batch_sharding_fn=engine.batch_sharding_fn,
        n_clients=engine.n_clients,
        terms=engine.terms,
        engine=engine,
    )
