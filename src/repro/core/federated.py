"""``make_fl_train_step`` — one jit-compiled FL round, composing:

  local updating (FedAvg E epochs / FedSGD / FedProx / SCAFFOLD)
  -> client selection (all / random / power-of-choice / multi-criteria)
  -> compressed shard_map aggregation (CommPipeline, state threaded
     through FLState.comm_state — error feedback / DGC momentum are
     wrapping transforms owned by the pipeline, not this trainer)
  -> server optimizer (FedAvg / FedAvgM / FedAdam / FedYogi)
  -> communication ledger

Batch layout (client-major; ``C`` = number of FL clients on the mesh):
  tokens/labels/mask : (C, B_local, S)
  sizes              : (C,)      client dataset sizes (FedAvg weighting)
  resources          : (C, 4)    simulated device profile (FedMCCS)
  [+ patches / frontend for vlm / encdec archs]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compress.api import Identity, make_compressor
from repro.compress.pipeline import error_feedback, momentum_correction
from repro.core import aggregation, selection as sel, server_opt
from repro.core.types import ArchConfig, CommLedger, FLConfig, FLState
from repro.models import sharding as shd
from repro.models.model import Model

PyTree = Any


# ---------------------------------------------------------------------------
# Static ledger terms (bits per selected client per round)
# ---------------------------------------------------------------------------

def uplink_pipeline(fl: FLConfig):
    """The uplink CommPipeline from config: the spec string (legacy name or
    ``"a:x>>b:y"`` chain) plus the stateful correction wrapper — DGC momentum
    correction if ``dgc_momentum`` is set, else error feedback for biased
    pipelines. Wrappers leave wire/entropy bits unchanged."""
    up = make_compressor(fl.uplink_compressor, fraction=fl.topk_fraction,
                         block=fl.qsgd_block, rows=fl.sketch_rows,
                         cols=fl.sketch_cols)
    if fl.dgc_momentum > 0.0 and not up.is_identity:
        up = momentum_correction(up, fl.dgc_momentum)
    elif up.biased and fl.error_feedback:
        up = error_feedback(up)
    return up


def ledger_terms(model: Model, fl: FLConfig):
    up = uplink_pipeline(fl)
    down = make_compressor(fl.downlink_compressor, block=fl.qsgd_block)
    sizes = [int(np.prod(d.shape)) for d in
             jax.tree.leaves(model.defs, is_leaf=lambda x: hasattr(x, "logical"))]
    # SCAFFOLD ships control variates, FedDANE ships a gradient round: 2x
    scaff = 2.0 if fl.algorithm in ("scaffold", "feddane") else 1.0
    t = {
        "up_wire": scaff * sum(up.wire_bits(n) for n in sizes) / 8.0,
        "up_entropy": scaff * sum(up.entropy_bits(n) for n in sizes) / 8.0,
        "down_wire": sum(down.wire_bits(n) for n in sizes) / 8.0,
        "dense": sum(32.0 * n for n in sizes) / 8.0,
    }
    return t, up, down


# ---------------------------------------------------------------------------
# Client local update
# ---------------------------------------------------------------------------

def _client_update(model: Model, fl: FLConfig, params, batch_c, rng,
                   control, c_i, chunk, global_grad=None):
    """One client's local training. Returns (delta, mean_loss, first_loss,
    new_c_i). For ``feddane`` [49], ``global_grad`` is the aggregated
    gradient at the global params; the local steps use the DANE-corrected
    gradient g_i(w') + (g(w) − g_i(w)) + mu·(w' − w)."""
    E, lr = fl.local_steps, fl.local_lr
    loss_fn = lambda p: model.loss(p, batch_c, chunk=chunk)[0]

    ddt = jnp.bfloat16 if fl.delta_dtype == "bf16" else jnp.float32
    fast = (E == 1 and fl.algorithm in ("fedavg", "fedsgd")
            and fl.fedprox_mu == 0.0)
    if fast:
        loss, g = jax.value_and_grad(loss_fn)(params)
        delta = jax.tree.map(lambda g_: (-lr * g_).astype(ddt), g)
        return delta, loss, loss, c_i

    dane_corr = None
    if fl.algorithm == "feddane" and global_grad is not None:
        g_i0 = jax.grad(loss_fn)(params)
        dane_corr = jax.tree.map(
            lambda gg, gi: gg.astype(jnp.float32) - gi.astype(jnp.float32),
            global_grad, g_i0)

    def step(p_c, _):
        loss, g = jax.value_and_grad(loss_fn)(p_c)
        if fl.algorithm in ("fedprox", "feddane") and fl.fedprox_mu:
            g = jax.tree.map(
                lambda g_, pc, p0: g_ + fl.fedprox_mu * (pc - p0).astype(g_.dtype),
                g, p_c, params)
        if dane_corr is not None:
            g = jax.tree.map(lambda g_, d: g_ + d.astype(g_.dtype),
                             g, dane_corr)
        if fl.algorithm == "scaffold":
            g = jax.tree.map(
                lambda g_, c, ci: g_ + (c - ci).astype(g_.dtype), g, control, c_i)
        p_c = jax.tree.map(lambda a, g_: (a.astype(jnp.float32)
                                          - lr * g_.astype(jnp.float32)
                                          ).astype(a.dtype), p_c, g)
        return p_c, loss

    p_fin, losses = jax.lax.scan(step, params, None, length=E)
    delta = jax.tree.map(
        lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32))
        .astype(ddt), p_fin, params)
    new_c_i = c_i
    if fl.algorithm == "scaffold":
        new_c_i = jax.tree.map(
            lambda ci, c, d: ci - c - d / (E * lr), c_i, control, delta)
    return delta, losses.mean(), losses[0], new_c_i


# ---------------------------------------------------------------------------
# Step builder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FLTrainStep:
    init_fn: Any            # (rng, batch_like) -> FLState (sharded)
    step_fn: Any            # (state, batch) -> (state, metrics)  [jitted]
    state_shardings: Any
    batch_sharding_fn: Any  # batch pytree -> shardings
    n_clients: int
    terms: dict


def make_fl_train_step(model: Model, fl: FLConfig, mesh: Mesh,
                       chunk: int = 512) -> FLTrainStep:
    cfg = model.cfg
    axes = aggregation.client_axes(mesh, cfg.client_axis)
    C = int(np.prod([dict(mesh.shape)[a] for a in axes])) if axes else 1
    client_p = P(axes) if axes else P()

    abs_params = model.abstract_params()
    pspecs = shd.tree_specs(abs_params, model.logical_axes(),
                            mesh, cfg.fsdp)
    terms, up_comp, down_comp = ledger_terms(model, fl)
    aggregate = aggregation.make_aggregator(mesh, pspecs, up_comp,
                                            cfg.client_axis,
                                            abstract_params=abs_params)
    agg_ctrl = (aggregation.make_aggregator(mesh, pspecs, Identity(),
                                            cfg.client_axis)
                if fl.algorithm == "scaffold" else None)
    scaffold = fl.algorithm == "scaffold"
    stateful = up_comp.stateful

    # --- shardings ----------------------------------------------------------
    def _shard(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    clientful = shd.with_prefix(pspecs, axes if axes else None)
    state_specs = FLState(
        params=pspecs,
        server_opt_state={k: pspecs
                          for k in server_opt.state_keys(fl.server_opt)},
        control=pspecs if scaffold else None,
        client_controls=clientful if scaffold else None,
        comm_state=(aggregation.comm_state_specs(up_comp, abs_params, pspecs,
                                                 axes)
                    if stateful else None),
        rng=P(), round=P(),
    )

    # --- init ----------------------------------------------------------------
    def init_fn(rng):
        params = model.init(rng)
        zerosf32 = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zeros_clientful = lambda: jax.tree.map(
            lambda p: jnp.zeros((C,) + p.shape, jnp.float32), params)
        return FLState(
            params=params,
            server_opt_state=server_opt.init_state(fl.server_opt, params),
            control=zerosf32() if scaffold else None,
            client_controls=zeros_clientful() if scaffold else None,
            comm_state=(aggregation.comm_state_init(up_comp, params, C)
                        if stateful else None),
            rng=jax.random.PRNGKey(fl.seed),
            round=jnp.zeros((), jnp.int32),
        )

    # --- the round ------------------------------------------------------------
    def step_fn(state: FLState, batch):
        rng, r_down, r_sel, r_up, r_next = jax.random.split(state.rng, 5)

        # downlink (LFL): clients train from a quantised global model
        params = state.params
        if not down_comp.is_identity:
            flatp = jax.tree.map(lambda p: p.reshape(-1).astype(jnp.float32),
                                 params)
            params = jax.tree.map(
                lambda p, f: down_comp.roundtrip(r_down, f)
                .reshape(p.shape).astype(p.dtype), params, flatp)

        # local updates, vmapped over the client axis
        ctrl = state.control if scaffold else None
        rngs = jax.random.split(rng, C)

        def upd(batch_c, r, ci):
            return _client_update(model, fl, params, batch_c, r, ctrl, ci, chunk)

        model_batch = {k: v for k, v in batch.items()
                       if k not in ("sizes", "resources")}
        if scaffold:
            deltas, losses, first_losses, new_ci = jax.vmap(upd)(
                model_batch, rngs, state.client_controls)
        else:
            deltas, losses, first_losses, _ = jax.vmap(
                lambda b, r: upd(b, r, None))(model_batch, rngs)
            new_ci = None

        # selection -> per-client weights
        sizes = batch.get("sizes", jnp.ones((C,), jnp.float32))
        resources = batch.get("resources", jnp.ones((C, 4), jnp.float32))
        weights = sel.select(fl, r_sel, losses=first_losses,
                             resources=resources, sizes=sizes)
        n_sel = (weights > 0).sum().astype(jnp.float32)

        # compressed aggregation over the wire (pipeline state rides along)
        agg_delta, new_comm = aggregate(deltas, weights, r_up,
                                        state.comm_state)
        if scaffold:
            # unselected clients keep their control variate
            selmask = (weights > 0).astype(jnp.float32)
            new_ci = jax.tree.map(
                lambda new, old: jnp.where(
                    selmask.reshape((C,) + (1,) * (new.ndim - 1)) > 0, new, old),
                new_ci, state.client_controls)
            dci = jax.tree.map(lambda a, b: a - b, new_ci,
                               state.client_controls)
            agg_dc, _ = agg_ctrl(dci, weights, r_up, None)
            control = jax.tree.map(
                lambda c, d: c + (n_sel / C) * d, state.control, agg_dc)
        else:
            control = None

        new_params, new_sos = server_opt.apply(fl, state.params, agg_delta,
                                               state.server_opt_state)

        ledger = CommLedger(
            uplink_wire=n_sel * terms["up_wire"],
            uplink_entropy=n_sel * terms["up_entropy"],
            downlink_wire=n_sel * terms["down_wire"],
            uplink_dense=n_sel * terms["dense"],
            downlink_dense=n_sel * terms["dense"],
        )
        metrics = {
            "loss": (weights * losses).sum() / jnp.maximum(weights.sum(), 1e-9),
            "loss_all": losses.mean(),
            "selected": n_sel,
            "ledger": ledger,
        }
        new_state = FLState(
            params=new_params, server_opt_state=new_sos, control=control,
            client_controls=new_ci, comm_state=new_comm,
            rng=r_next, round=state.round + 1,
        )
        return new_state, metrics

    state_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P))

    def batch_sharding_fn(batch):
        """Client dim -> client axes; for pod-clients the within-client batch
        dim additionally shards over the data axis."""
        out = {}
        sub = ("data",) if (cfg.client_axis == "pod"
                            and "data" in mesh.axis_names) else ()
        lead = tuple(client_p) or (None,)
        for k, v in batch.items():
            nd = np.ndim(v) if not hasattr(v, "ndim") else v.ndim
            if nd == 0:
                out[k] = NamedSharding(mesh, P())
            elif nd <= 2 or not sub:
                # (C,) / (C, small) metadata: client axes only
                out[k] = NamedSharding(mesh, P(*lead))
            else:
                # (C, B, ...) model inputs: within-client batch over data
                out[k] = NamedSharding(mesh, P(*lead, *sub))
        return out

    return FLTrainStep(
        init_fn=init_fn,
        step_fn=step_fn,
        state_shardings=state_shardings,
        batch_sharding_fn=batch_sharding_fn,
        n_clients=C,
        terms=terms,
    )
