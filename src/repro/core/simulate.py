"""Single-device FL simulator — same round semantics as ``federated.py``
(local update -> selection -> compress/decompress -> server opt -> ledger)
but with the client count decoupled from the mesh (plain vmap, no shard_map).

This is the *experiment* path: the paper-faithful convergence reproductions
(benchmarks/, examples/) run here on CPU with dozens of clients, while
``federated.make_fl_train_step`` is the *deployment* path where clients map
onto mesh axes and compression rides the collectives. Both share
``_client_update``, the compressor registry, selection and the ledger — so a
claim validated here transfers to the deployed step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import selection as sel, server_opt
from repro.core.aggregation import comm_state_init
from repro.core.federated import _client_update, ledger_terms
from repro.core.types import CommLedger, FLConfig, FLState
from repro.models.model import Model


@dataclasses.dataclass
class SimFL:
    init_fn: Any
    step_fn: Any           # jit'd (state, batch) -> (state, metrics)
    n_clients: int
    terms: dict


def make_sim_step(model: Model, fl: FLConfig, n_clients: int,
                  chunk: int = 64) -> SimFL:
    C = n_clients
    terms, up, down = ledger_terms(model, fl)
    scaffold = fl.algorithm == "scaffold"
    stateful = up.stateful

    def init_fn(rng):
        params = model.init(rng)
        zc = lambda: jax.tree.map(
            lambda p: jnp.zeros((C,) + p.shape, jnp.float32), params)
        zf = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return FLState(
            params=params,
            server_opt_state=server_opt.init_state(fl.server_opt, params),
            control=zf() if scaffold else None,
            client_controls=zc() if scaffold else None,
            comm_state=comm_state_init(up, params, C) if stateful else None,
            rng=jax.random.PRNGKey(fl.seed),
            round=jnp.zeros((), jnp.int32),
            prev_delta=zf() if fl.cmfl_threshold > 0 else None,
        )

    def step_fn(state: FLState, batch):
        rng, r_down, r_sel, r_up, r_next = jax.random.split(state.rng, 5)

        params = state.params
        if not down.is_identity:
            params = jax.tree.map(
                lambda p: down.roundtrip(r_down, p.reshape(-1).astype(
                    jnp.float32)).reshape(p.shape).astype(p.dtype), params)

        ctrl = state.control if scaffold else None
        rngs = jax.random.split(rng, C)
        model_batch = {k: v for k, v in batch.items()
                       if k not in ("sizes", "resources")}

        # FedDANE [49]: one extra communication round — aggregate the global
        # gradient at w before the corrected local solves (ledger counts 2x)
        gg = None
        if fl.algorithm == "feddane":
            g_each = jax.vmap(lambda b: jax.grad(
                lambda p: model.loss(p, b, chunk=chunk)[0])(params))(
                model_batch)
            gg = jax.tree.map(lambda g: g.astype(jnp.float32).mean(0), g_each)

        if scaffold:
            deltas, losses, first_losses, new_ci = jax.vmap(
                lambda b, r, ci: _client_update(model, fl, params, b, r,
                                                ctrl, ci, chunk))(
                model_batch, rngs, state.client_controls)
        else:
            deltas, losses, first_losses, _ = jax.vmap(
                lambda b, r: _client_update(model, fl, params, b, r,
                                            None, None, chunk,
                                            global_grad=gg))(
                model_batch, rngs)
            new_ci = None

        sizes = batch.get("sizes", jnp.ones((C,), jnp.float32))
        resources = batch.get("resources", jnp.ones((C, 4), jnp.float32))
        weights = sel.select(fl, r_sel, losses=first_losses,
                             resources=resources, sizes=sizes)

        # CMFL [35]: drop updates whose sign-agreement with the previous
        # global update falls below the threshold (they are "irrelevant" and
        # never uploaded — the ledger sees the reduced n_sel)
        if fl.cmfl_threshold > 0:
            d_flat = jnp.concatenate([l.reshape(C, -1) for l in
                                      jax.tree.leaves(deltas)], axis=1)
            p_flat = jnp.concatenate([l.reshape(-1) for l in
                                      jax.tree.leaves(state.prev_delta)])
            rel = (jnp.sign(d_flat) == jnp.sign(p_flat)[None, :]) \
                .mean(axis=1)
            rel = jnp.where(state.round == 0, 1.0, rel)   # warm-up round
            weights = weights * (rel >= fl.cmfl_threshold)
        n_sel = (weights > 0).sum().astype(jnp.float32)
        wsum = jnp.maximum(weights.sum(), 1e-9)

        # encode each client's leaf, decode, weighted mean — the pipeline
        # owns its correction state (EF residual / DGC momentum), vmapped
        # over clients alongside the deltas
        d_leaves, dtree = jax.tree.flatten(deltas)
        agg_leaves, st_leaves = [], []
        for li, leaf in enumerate(d_leaves):
            shape = leaf.shape[1:]
            flat = leaf.reshape(C, -1).astype(jnp.float32)
            rs = jax.vmap(lambda r: jax.random.fold_in(r, li))(rngs)
            if stateful:
                def one(x, r, st):
                    payload, nst = up.encode(st, r, x)
                    return up.decode(payload, x.shape[0]), nst
                dec, nst = jax.vmap(one)(flat, rs, state.comm_state[li])
                st_leaves.append(nst)
            else:
                def one(x, r):
                    payload, _ = up.encode(up.init(x.shape), r, x)
                    return up.decode(payload, x.shape[0])
                dec = jax.vmap(one)(flat, rs)
            agg_leaves.append(((weights[:, None] * dec).sum(0) / wsum)
                              .reshape(shape))
        agg = jax.tree.unflatten(dtree, agg_leaves)
        new_comm = tuple(st_leaves) if stateful else None

        if scaffold:
            selmask = (weights > 0).astype(jnp.float32)
            new_ci = jax.tree.map(
                lambda new, old: jnp.where(
                    selmask.reshape((C,) + (1,) * (new.ndim - 1)) > 0,
                    new, old), new_ci, state.client_controls)
            dci = jax.tree.map(lambda a, b: ((weights[:, None].reshape(
                (C,) + (1,) * (a.ndim - 1)) * (a - b)).sum(0) / wsum),
                new_ci, state.client_controls)
            control = jax.tree.map(lambda c, d: c + (n_sel / C) * d,
                                   state.control, dci)
        else:
            control = None

        agg = jax.tree.map(lambda a, p: a.astype(jnp.float32), agg,
                           state.params)
        new_params, new_sos = server_opt.apply(fl, state.params, agg,
                                               state.server_opt_state)
        ledger = CommLedger(
            uplink_wire=n_sel * terms["up_wire"],
            uplink_entropy=n_sel * terms["up_entropy"],
            downlink_wire=n_sel * terms["down_wire"],
            uplink_dense=n_sel * terms["dense"],
            downlink_dense=n_sel * terms["dense"])
        metrics = {"loss": (weights * losses).sum() / wsum,
                   "loss_all": losses.mean(), "selected": n_sel,
                   "ledger": ledger}
        new_prev = agg if fl.cmfl_threshold > 0 else None
        return FLState(params=new_params, server_opt_state=new_sos,
                       control=control, client_controls=new_ci,
                       comm_state=new_comm, rng=r_next,
                       round=state.round + 1, prev_delta=new_prev), metrics

    return SimFL(init_fn=init_fn, step_fn=jax.jit(step_fn),
                 n_clients=C, terms=terms)


def evaluate(model: Model, params, batch, chunk=64) -> float:
    loss, _ = model.loss(params, batch, chunk=chunk)
    return float(loss)
