"""Single-device FL simulator — the ``Topology.sim`` binding of the
RoundEngine: identical round semantics to ``federated.py`` (the two share
the engine's hop sequence verbatim) but with the client count decoupled
from the mesh (plain vmap, no shard_map).

This is the *experiment* path: the paper-faithful convergence reproductions
(benchmarks/, examples/) run here on CPU with dozens of clients, while
``federated.make_fl_train_step`` is the *deployment* path where clients map
onto mesh axes and compression rides the collectives. Both run the same
``RoundProgram`` hops — only the wire hop differs — so a claim validated
here transfers to the deployed step. The sim topology additionally enables
the simulation-only hops: FedDANE's gradient round and CMFL relevance
filtering.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core.engine import Topology, make_round_engine
from repro.core.types import FLConfig
from repro.models.model import Model


@dataclasses.dataclass
class SimFL:
    init_fn: Any
    step_fn: Any           # jit'd (state, batch) -> (state, metrics)
    n_clients: int
    terms: dict
    engine: Any = None     # the underlying RoundEngine (for run_rounds)


def make_sim_step(model: Model, fl: FLConfig, n_clients: int,
                  chunk: int = 64) -> SimFL:
    engine = make_round_engine(model, fl, Topology.sim(n_clients),
                               chunk=chunk)
    return SimFL(init_fn=engine.init_fn,
                 step_fn=jax.jit(engine.round_fn),
                 n_clients=engine.n_clients,
                 terms=engine.terms,
                 engine=engine)


def evaluate(model: Model, params, batch, chunk=64) -> float:
    loss, _ = model.loss(params, batch, chunk=chunk)
    return float(loss)
