"""Scenario — realistic client dynamics behind the static config surface
(DESIGN.md §13).

The survey's client landscape is richer than a single static latency draw:
devices join and vanish on diurnal schedules, drop mid-round, and chronic
stragglers should not be asked for the same local work as fast clients.
This module is the one place those dynamics are *defined*; the engines
consume it behind static-shape, mask-based semantics so every scenario has
a bit-exact OFF path (tests/test_scenario.py):

  * **availability traces** — :func:`availability_mask` generalizes the
    i.i.d. Bernoulli draw (``trace="static"``, op-for-op the historical
    ``ClientPopulation.availability_mask``) to per-client phase-shifted
    ``"square"`` duty windows and ``"diurnal"`` sinusoid-modulated
    Bernoulli schedules.  Both hit the configured duty cycle in
    time-average by construction (the sinusoid's amplitude is clamped to
    ``min(rate, 1-rate)`` so its mean is exactly ``rate``).
  * **mid-round dropout** — :func:`survival_mask` / :func:`survival_draw`:
    a per-(round, client) survival draw ``P = exp(-hazard * latency)``
    against the client's elapsed virtual time.  Dropped clients become
    zero-weight rows in ``Dispatch.aggregate_rows`` (partial-update
    semantics; payload shapes never change, and under secagg the decode
    unmasks per client via the payload ctx, so zero-weighting cannot
    corrupt the aggregate — tests/test_secure_agg.py).
  * **heterogeneity-aware dispatch** — :func:`epoch_steps`: the FedMCCS
    capability latency drives a per-client local-epoch scale
    ``clip(median(lat)/lat_i, floor, 1)``, so chronic stragglers run
    fewer local steps instead of only being staleness-decayed.
  * **adaptive deadline arming** — :func:`quantile_update`: a
    Robbins-Monro completion-time quantile tracker kept in
    ``async_state``; the AsyncEngine arms ``next_deadline = clock +
    q_est`` from it instead of a fixed ``async_flush_deadline``.

Everything is keyed by ``jax.random.fold_in`` on (seed, round, id), never
by carried RNG state, so masks are pure in (config, round) and any two
consumers (the selection hop, ``ClientPopulation``, a test) recompute
identical masks — the availability seam fix.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.data.pipeline import capability_latency

TRACES = ("static", "diurnal", "square")

# fold_in salts.  _AVAIL_SALT is pinned to ClientPopulation's historical
# Bernoulli key derivation (seed + 13) — changing it would silently re-draw
# every availability mask shipped since PR 6.
_AVAIL_SALT = 13
_PHASE_SALT = 29
_DROP_SALT = 31


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Client-dynamics configuration.  Every default encodes "off": a
    default-constructed Scenario is ``enabled == False`` and the engines
    statically skip every scenario hop (the differential conformance
    contract).  Reachable from ``FLConfig.scenario_*`` via
    :meth:`from_fl` and from ``launch/train.py``'s ``--scenario-*``
    flags."""

    trace: str = "static"             # static | diurnal | square
    period: float = 24.0              # trace period, in rounds
    availability: float = 1.0         # duty-cycle rate (dense sim/star path;
    #                                   a ClientPopulation keeps its own rate)
    dropout: float = 0.0              # mid-round dropout hazard per unit
    #                                   virtual time (0 = off)
    epoch_scale: float = 0.0          # 0 = off; else the floor in (0, 1] of
    #                                   the per-client local-epoch scale
    deadline_quantile: float = 0.0    # 0 = off; else the completion-time
    #                                   quantile the async deadline tracks
    seed: int = 0

    def __post_init__(self):
        if self.trace not in TRACES:
            raise ValueError(
                f"scenario trace {self.trace!r} not in {TRACES}")
        if not self.period > 0:
            raise ValueError("scenario period must be > 0 rounds")
        if not 0.0 < self.availability <= 1.0:
            raise ValueError("scenario availability must be in (0, 1]")
        if self.dropout < 0.0:
            raise ValueError("scenario dropout hazard must be >= 0")
        if not 0.0 <= self.epoch_scale <= 1.0:
            raise ValueError("scenario epoch_scale must be in [0, 1]")
        if not 0.0 <= self.deadline_quantile < 1.0:
            raise ValueError("scenario deadline_quantile must be in [0, 1)")

    @staticmethod
    def from_fl(fl) -> "Scenario":
        return Scenario(trace=fl.scenario_trace,
                        period=fl.scenario_period,
                        availability=fl.scenario_availability,
                        dropout=fl.scenario_dropout,
                        epoch_scale=fl.scenario_epoch_scale,
                        deadline_quantile=fl.scenario_deadline_quantile,
                        seed=fl.scenario_seed)

    @property
    def diurnal(self) -> bool:
        """True when the availability trace is time-varying."""
        return self.trace != "static"

    @property
    def availability_on(self) -> bool:
        """True when the dense (no-population) path must draw a mask."""
        return self.diurnal or self.availability < 1.0

    @property
    def enabled(self) -> bool:
        """Any dynamics at all?  False ⇒ the engines build today's exact
        graphs (no scenario hop, no extra async_state keys)."""
        return (self.diurnal or self.availability < 1.0
                or self.dropout > 0.0 or self.epoch_scale > 0.0
                or self.deadline_quantile > 0.0)


# ---------------------------------------------------------------------------
# availability traces
# ---------------------------------------------------------------------------

def bernoulli_mask(seed: int, rate: float, round_idx, ids):
    """THE i.i.d. Bernoulli availability draw — the single implementation
    behind both ``ClientPopulation.availability_mask`` and the dense
    selection-hop path, pinned op-for-op to the PR-6 semantics: per-round
    key ``fold_in(PRNGKey(seed + 13), round)``, one uniform per client id,
    ``u < rate``.  (seed, round, id) fully determine the mask."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed + _AVAIL_SALT),
                             round_idx)
    u = jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(key, i)))(ids)
    return (u < rate).astype(jnp.float32)


def client_phases(seed: int, ids):
    """Per-client diurnal phase offsets, U[0, 1), *round-independent*
    (keyed on id only) — a client keeps its timezone across rounds."""
    key = jax.random.PRNGKey(seed + _PHASE_SALT)
    return jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(key, i)))(ids)


def availability_mask(scenario, seed: int, rate: float, round_idx, ids):
    """Availability under the scenario's trace; the shared entry point.

    * ``scenario is None`` / ``trace="static"`` — :func:`bernoulli_mask`
      (bit-exact historical behavior).
    * ``"square"`` — deterministic duty window: client *i* is available iff
      ``frac(round/period + phase_i) < rate``; exact ``rate`` duty cycle
      per client over a full period, clients joining/vanishing on a
      schedule rather than per-round coin flips.
    * ``"diurnal"`` — Bernoulli with sinusoidally modulated rate
      ``p_i(t) = rate + min(rate, 1-rate) * sin(2*pi*(t/period + phase_i))``;
      the amplitude clamp keeps ``p`` in [0, 1] and its time-average at
      exactly ``rate``.

    At ``rate == 1.0`` every trace degenerates to all-ones (``u < 1`` for
    ``u ~ U[0, 1)``, and ``frac < 1`` always) — the conformance anchor."""
    if scenario is None or scenario.trace == "static":
        return bernoulli_mask(seed, rate, round_idx, ids)
    phi = client_phases(scenario.seed, ids)
    t = round_idx.astype(jnp.float32) / jnp.float32(scenario.period)
    frac = jnp.mod(t + phi, 1.0)
    if scenario.trace == "square":
        return (frac < rate).astype(jnp.float32)
    amp = min(rate, 1.0 - rate)
    p = rate + jnp.float32(amp) * jnp.sin(2.0 * math.pi * frac)
    key = jax.random.fold_in(jax.random.PRNGKey(seed + _AVAIL_SALT),
                             round_idx)
    u = jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(key, i)))(ids)
    return (u < p).astype(jnp.float32)


# ---------------------------------------------------------------------------
# mid-round dropout
# ---------------------------------------------------------------------------

def survival_prob(scenario, latency):
    """P(client finishes the round) = exp(-hazard * elapsed virtual time):
    an exponential failure clock running while the client computes and
    uploads — slower devices are exposed longer and drop more often."""
    return jnp.exp(-jnp.float32(scenario.dropout)
                   * jnp.asarray(latency, jnp.float32))


def survival_mask(scenario, round_idx, ids, latency):
    """Vectorized per-(round, client) survival draw for the synchronous
    engines.  (seed, round, id) determine the coin; ``latency`` is the
    deterministic capability base (:func:`capability_latency`)."""
    key = jax.random.fold_in(jax.random.PRNGKey(scenario.seed + _DROP_SALT),
                             round_idx)
    u = jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(key, i)))(ids)
    return (u < survival_prob(scenario, latency)).astype(jnp.float32)


def survival_draw(scenario, event_idx, client_id, latency):
    """Scalar flavour for the AsyncEngine: one draw per arrival event,
    keyed (event, client) so re-dispatches of the same slot re-flip."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(scenario.seed + _DROP_SALT),
                           event_idx), client_id)
    u = jax.random.uniform(key)
    return (u < survival_prob(scenario, latency)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# heterogeneity-aware dispatch (FedMCCS local-epoch scaling)
# ---------------------------------------------------------------------------

def epoch_steps(scenario, local_steps: int, resources):
    """Per-client local-step budgets from the FedMCCS capability profile.

    ``scale_i = clip(median(lat) / lat_i, floor, 1)`` with ``lat`` the
    deterministic capability latency: the median device runs the full
    ``local_steps``, chronic stragglers run a proportionally smaller
    budget, floored at ``scenario.epoch_scale`` (and never below one
    step).  Returns ``(n_steps (C,) int32, scale (C,) float32)``."""
    lat = capability_latency(resources)
    scale = jnp.clip(jnp.median(lat) / lat,
                     jnp.float32(scenario.epoch_scale), 1.0)
    n = jnp.maximum(1, jnp.round(local_steps * scale)).astype(jnp.int32)
    return n, scale


# ---------------------------------------------------------------------------
# adaptive deadline arming (completion-time quantile tracking)
# ---------------------------------------------------------------------------

QUANTILE_ETA = 0.05


def quantile_init(latency):
    """Initial completion-time estimate: the mean of the first dispatch
    generation's latencies (deterministic given the batch/profile)."""
    return jnp.asarray(latency, jnp.float32).mean()


def quantile_update(q, x, quantile: float, eta: float = QUANTILE_ETA):
    """One Robbins-Monro step of the quantile tracker:

        q  <-  q + step * (quantile - 1[x < q]),   step = eta * q

    The indicator's expectation at the stationary point is exactly the
    target quantile of the completion-time distribution; the multiplicative
    step makes convergence scale-free in the latency units (oscillation
    amplitude ~ eta * q).  Clamped below so a pathological q cannot get
    stuck at zero."""
    step = jnp.maximum(jnp.float32(eta) * q, 1e-4)
    ind = (jnp.asarray(x, jnp.float32) < q).astype(jnp.float32)
    return q + step * (jnp.float32(quantile) - ind)
