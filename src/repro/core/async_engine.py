"""AsyncEngine — virtual-clock asynchronous FL (DESIGN.md §7–§8).

The survey names asynchronous / semi-asynchronous updating as the third
communication-efficiency lever next to compression and selection: once the
wire is compressed, *stragglers* — not bytes — dominate round time.  This
module opens that workload as a new ``Topology.async_`` binding of the
RoundEngine: a **virtual-clock event simulator** in which every client slot
draws a per-dispatch latency from its simulated device profile
(``data.pipeline.device_latency`` over the FedMCCS resource vectors) and the
server consumes completions in virtual-time order.  Measured result: FedBuff
reaches the paper_lm target loss in ~2.4x less virtual wall-clock than sync
FedAvg under heavy-tail stragglers at the same upload budget
(EXPERIMENTS.md §Async, ``benchmarks.run --only async``).

One ``run_rounds`` step == one **server event** (a client upload arriving):

    pop      — argmin over the (C,) next-completion-time vector (no host
               priority queue; ties break to the lowest client index, so the
               degenerate constant-latency case pops in client order);
    arrive   — the completing client's *already-encoded* payload is
               delivered: its staleness ``tau`` (server_version now minus
               server_version at its dispatch) and FedAsync weight
               ``(1 + tau)^(-alpha)`` are recorded, and its pending
               ``comm_state`` row (EF residual / DGC momentum advanced when
               the payload was produced) is committed;
    flush    — when the FedBuff buffer holds ``buffer_size`` updates OR the
               virtual clock passes the flush deadline
               (``async_flush_deadline`` > 0 — adaptive buffer sizing,
               DESIGN.md §8), the server aggregates the buffer
               staleness-weighted, applies the server optimizer with the
               buffer's **mean staleness** (staleness-scaled FedAdam/FedYogi
               moments, ``core.server_opt``), bumps ``server_version``, and
               re-dispatches exactly the buffered clients on the new model
               (contributors receive the model their own updates produced —
               FedBuff's server-side downlink ordering);
    ledger   — per-event CommLedger rows carry ``virtual_time`` so
               bytes-to-target and time-to-target read off one stack.

Wire formats ride through unchanged: the buffered rows are whatever the
shared dispatch's uplink pipeline emits, so ``FLConfig.wire_format=
"packed"`` / per-stage ``@fused`` specs (DESIGN.md §10) move the bit-packed
payload through dispatch, buffer, and flush with no async-specific code —
the per-event ledger rows bill the packed byte counts
(tests/test_kernel_parity.py::test_async_engine_moves_packed_payloads).

**Dispatch is the shared body** (DESIGN.md §8): downlink, the batched
local-update vmap, the wire-boundary ``optimization_barrier``, and the
batched CommPipeline encode/decode all come from
``core.engine.make_dispatch`` — the *same* ``Dispatch`` object the
synchronous sim wire is built on, not a mirror of it.  That makes the
degenerate equivalence (buffer = C, constant latency == sync ``Topology.sim``
bit-exactly, params AND comm_state, with fedavg and staleness-scaled fedadam
server optimizers alike) **structural**: a change to the sync wire *is* a
change to the async wire.  A client's pipeline state is untouched between
its dispatch and its upload (only its own uploads mutate its row), so
encoding at dispatch is semantically identical to encoding at completion —
real clients encode before transmitting, and the straggler delay is in
*delivery*.  Keeping the whole dispatch in one graph also sidesteps an XLA
trap: per-completion wire hops would split the delta -> error-feedback-add
across programs, and XLA's FMA contraction (which reaches across
``lax.optimization_barrier``) makes split-program arithmetic differ from
fused-program arithmetic at ULP level (DESIGN.md §7).

Everything is static-shape inside the scan: the buffer is a (C,)-slotted
tree masked by ``isinf(next_done)`` (a client uploads at most once per
dispatch, so client-keyed slots never collide), and the flush runs under a
``lax.cond``.

**Equivalence contract** (structural via the shared dispatch body AND
re-proved in tests/test_async.py): with ``latency_profile="constant"`` and
``buffer_size == n_clients`` the event stream degenerates to synchronous
rounds — C pops in client order, one flush — and the AsyncEngine reproduces
the synchronous ``Topology.sim`` trajectory **bit-exactly**: the rng split
schedule, per-client update rngs, wire encode rngs, aggregation weight
algebra, and server-opt call are the identical computation graph,
``(1 + 0)^(-alpha) == 1.0`` exactly in IEEE arithmetic (the FedAsync weight
AND the FedAdam moment scale), and a disabled deadline adds no ops.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import scenario as scn_mod
from repro.core import server_opt
from repro.core.aggregation import comm_state_init
from repro.core.types import CommLedger, FLConfig, FLState
from repro.data.pipeline import LATENCY_PROFILES, device_latency
from repro.models.model import Model
from repro.obs import telemetry as obs_tel

_INF = jnp.float32(jnp.inf)


def _async_knobs(fl: FLConfig, topo, n_slots: int = 0) -> tuple:
    """Resolve (buffer_size K, staleness alpha, latency profile, flush
    deadline): explicit Topology fields win, FLConfig fields are the
    CLI-facing fallback, K == 0 means full participation (K = every slot),
    and deadline == 0 means count-only flushing.  ``n_slots`` is the
    in-flight slot count — n_clients for the dense build, the cohort size
    for a ClientPopulation build."""
    C = n_slots or topo.n_clients
    K = topo.buffer_size or fl.async_buffer_size or C
    if not (1 <= K <= C):
        raise ValueError(f"async buffer_size must be in [1, n_slots]; "
                         f"got {K} with {C} slots")
    alpha = (topo.staleness_alpha if topo.staleness_alpha is not None
             else fl.staleness_alpha)
    profile = topo.latency_profile or fl.latency_profile
    if profile not in LATENCY_PROFILES:
        raise ValueError(f"unknown latency profile {profile!r}; "
                         f"have {LATENCY_PROFILES}")
    deadline = (topo.flush_deadline if topo.flush_deadline is not None
                else fl.async_flush_deadline)
    if deadline < 0:
        raise ValueError(f"async_flush_deadline must be >= 0 (0 disables "
                         f"deadline flushing); got {deadline}")
    return int(K), float(alpha), profile, float(deadline)


def build_async_engine(model: Model, fl: FLConfig, topo, data_fn,
                       chunk: int = 512, population=None):
    """Build the async event executor (a RoundEngine whose ``round_fn`` is
    one server event).  ``data_fn(version) -> batch`` must be traceable —
    the engine samples each dispatch generation's client batches *inside*
    the event scan, keyed on the server version at dispatch (the same
    function ``run_rounds`` receives, so a degenerate async run and a sync
    run see identical data).

    With a ``population`` (ClientPopulation, DESIGN.md §9) the in-flight
    slot axis shrinks from n_clients to ``population.cohort``: each slot
    hosts one sampled client (``slot_client``), latency/size draws come
    from the cohort batch (lazy per-cohort, never dense ``(C,)``), arrival
    commits write the client's pipeline row into the bounded residual
    store keyed by client id, and each flush re-dispatches the flushed
    slots onto a freshly sampled cohort.  ``data_fn`` must then be
    ``data.pipeline.cohort_data_fn`` over the same population so engine
    and data agree on the cohort ids."""
    # late import: async_engine <-> engine is a module cycle by design
    # (the builder lives here, the Topology/RoundEngine types live there)
    from repro.core import engine as eng

    if data_fn is None:
        raise ValueError("the async topology samples dispatch batches inside "
                         "the event scan — pass data_fn to make_round_engine")
    if fl.algorithm not in ("fedavg", "fedsgd", "fedprox"):
        raise ValueError(
            f"async topology supports fedavg/fedsgd/fedprox; "
            f"{fl.algorithm!r} needs synchronous control flow (SCAFFOLD "
            f"control variates / FedDANE's extra gradient round)")
    if fl.selection != "all" or fl.cmfl_threshold > 0:
        raise ValueError("async topology replaces client selection with "
                         "completion order — use selection='all' and "
                         "cmfl_threshold=0")
    if population is not None and population.n_clients != topo.n_clients:
        raise ValueError(
            f"population.n_clients ({population.n_clients}) must match "
            f"Topology.async_(n_clients={topo.n_clients})")

    # scenario dynamics (core.scenario, DESIGN.md §13): the async engine
    # takes mid-round dropout (survival draw per arrival), epoch scaling
    # (via the shared dispatch body), and adaptive deadline arming.
    # Availability traces act on the synchronous selection hop, which the
    # async topology replaces with completion order — reject rather than
    # silently ignore the knob.
    scenario = eng._fl_scenario(fl)
    if scenario is not None and scenario.availability_on:
        raise ValueError(
            "async topology replaces client selection with completion "
            "order, so scenario availability traces have no hop to mask — "
            "use scenario_trace='static' / scenario_availability=1.0 (the "
            "dropout / epoch-scale / deadline-quantile knobs all apply)")
    adaptive = scenario is not None and scenario.deadline_quantile > 0.0
    scn_drop = scenario is not None and scenario.dropout > 0.0
    # the popped slot's in-flight duration, needed by both the survival
    # draw and the completion-time quantile tracker
    need_lat = adaptive or scn_drop

    C = topo.n_clients
    # M: the in-flight slot count — every per-slot vector below is (M,).
    # Dense build: one slot per client.  Population build: one per cohort
    # member, with A["slot_client"] mapping slots to client ids.
    M = population.cohort if population is not None else C
    K, alpha, profile, deadline = _async_knobs(fl, topo, n_slots=M)
    terms, up, down = eng.ledger_terms(model, fl)
    tele = eng._telemetry_spec(fl, up, down, eng._param_sizes(model))
    stateful = up.stateful
    store = (population.make_store(up, model.abstract_params())
             if population is not None else None)
    # THE tentpole contract: this is the synchronous engine's dispatch body
    # (downlink >> local-update vmap >> wire-boundary barrier >> CommPipeline
    # encode/decode >> row aggregation), not a copy of it — DESIGN.md §8
    dispatch = eng.make_dispatch(model, fl, up, down, M, chunk,
                                 scenario=scenario)

    def init_fn(rng):
        params = model.init(rng)
        # generation-0 key schedule == the sync engine's round-0 split
        k_loc, k_down, k_sel, k_up, k_next = jax.random.split(
            jax.random.PRNGKey(fl.seed), 5)
        batch0 = data_fn(jnp.zeros((), jnp.int32))
        if store is not None:
            ids0 = population.cohort_ids(jnp.zeros((), jnp.int32))
            rows0, comm0 = store.gather(store.init(), ids0)
        else:
            comm0 = comm_state_init(up, params, M) if stateful else None
            rows0 = comm0
        # jit: eager arithmetic (e.g. the E=1 fast-path delta) differs from
        # the compiled scan's at ULP level via XLA FMA contraction, which
        # would break the degenerate bit-exactness contract
        updates, losses, pending = jax.jit(dispatch)(params, batch0, rows0,
                                                     k_loc, k_down, k_up)
        lat = device_latency(profile, batch0["resources"], k_sel)
        A = {
            "clock": jnp.zeros((), jnp.float32),
            "next_done": lat,                      # all M in flight
            "version": jnp.zeros((M,), jnp.int32),
            "server_version": jnp.zeros((), jnp.int32),
            "updates": updates,
            "buf_w": jnp.zeros((M,), jnp.float32),
            "buf_tau": jnp.zeros((M,), jnp.float32),
            "losses": losses,
            "next_deadline": jnp.float32(deadline if deadline > 0
                                         else jnp.inf),
        }
        if need_lat:
            # in-flight duration per slot: the survival draw's exposure
            # time and the quantile tracker's observation (core.scenario).
            # +0.0 forces a distinct buffer from next_done — both are
            # donated scan carries, and XLA rejects double donation
            A["slot_lat"] = lat + 0.0
        if adaptive:
            A["q_est"] = scn_mod.quantile_init(lat)
            # distinct buffer: q_est and next_deadline are both donated
            # scan carries, and XLA rejects donating one buffer twice
            A["next_deadline"] = A["q_est"] + 0.0
        if stateful:
            A["pending_comm"] = pending
        if population is not None:
            A["slot_client"] = population.cohort_ids(jnp.zeros((), jnp.int32))
            A["slot_size"] = batch0.get("sizes", jnp.ones((M,), jnp.float32))
        return FLState(
            params=params,
            server_opt_state=server_opt.init_state(fl.server_opt, params),
            control=None, client_controls=None,
            comm_state=comm0,
            rng=k_next,
            round=jnp.zeros((), jnp.int32),
            async_state=A,
        )

    # ------------------------------------------------------------------ hops
    def hop_pop(ctx):
        A = ctx["state"].async_state
        c = jnp.argmin(A["next_done"])             # ties -> lowest index
        ctx["c"] = c
        ctx["clock"] = jnp.maximum(A["clock"], A["next_done"][c])
        ctx["tau"] = A["server_version"] - A["version"][c]
        ctx["stale_w"] = (1.0 + ctx["tau"].astype(jnp.float32)) ** (-alpha)
        ctx["onehot"] = (jnp.arange(M) == c)
        return ctx

    def hop_arrive(ctx):
        """Delivery bookkeeping for ONE client: mark its slot in-buffer,
        record its staleness (weight for the aggregation, raw tau for the
        server optimizer's mean-staleness scale), and commit its pending
        comm_state row (the EF residual advanced when the payload was
        produced — only this client's own uploads touch its row, so commit
        order is safe)."""
        st, A = ctx["state"], ctx["state"].async_state
        A2 = dict(A)
        A2["next_done"] = jnp.where(ctx["onehot"], _INF, A["next_done"])
        if scn_drop:
            # mid-round dropout (DESIGN.md §13): one survival coin per
            # arrival event, exposure = the slot's in-flight duration.  A
            # dropped client's payload still arrives (shapes never change)
            # but lands with zero aggregation weight — the same partial-
            # update semantics as the sync engines' zero-weight rows.
            cid = (A["slot_client"][ctx["c"]] if population is not None
                   else ctx["c"])
            survive = scn_mod.survival_draw(scenario, st.round, cid,
                                            A["slot_lat"][ctx["c"]])
            ctx["scn_dropped"] = 1.0 - survive
            w_in = ctx["stale_w"] * survive
        else:
            w_in = ctx["stale_w"]
        if adaptive:
            # completion-time quantile tracker: one Robbins-Monro step per
            # observed arrival duration (scenario.quantile_update)
            A2["q_est"] = scn_mod.quantile_update(
                A["q_est"], A["slot_lat"][ctx["c"]],
                scenario.deadline_quantile)
        A2["buf_w"] = jnp.where(ctx["onehot"], w_in, A["buf_w"])
        A2["buf_tau"] = jnp.where(ctx["onehot"],
                                  ctx["tau"].astype(jnp.float32),
                                  A["buf_tau"])
        A2["clock"] = ctx["clock"]
        if store is not None:
            # commit the arriving slot's advanced pipeline row into the
            # residual store, keyed by the CLIENT id the slot hosts — the
            # wire boundary is the commit point (DESIGN.md §9): the server
            # has consumed this payload, so the residual advance is final
            c = ctx["c"]
            row_c = tuple(
                jax.tree.map(lambda p: p[c][None], A["pending_comm"][li])
                for li in range(len(A["pending_comm"])))
            ctx["new_comm"] = store.scatter(
                st.comm_state, A["slot_client"][c][None], row_c)
        elif stateful:
            sel = ctx["onehot"]
            ctx["new_comm"] = tuple(
                jax.tree.map(
                    lambda p, o: jnp.where(
                        sel.reshape((M,) + (1,) * (o.ndim - 1)), p, o),
                    A["pending_comm"][li], st.comm_state[li])
                for li in range(len(st.comm_state)))
        else:
            ctx["new_comm"] = None
        ctx["A"] = A2
        ctx["fill"] = jnp.isinf(A2["next_done"]).sum().astype(jnp.int32)
        return ctx

    def hop_flush(ctx):
        """FedBuff aggregation + next-generation dispatch under lax.cond.

        Fires on buffer count (fill >= K) OR — adaptive buffer sizing,
        ``async_flush_deadline`` > 0 — when the completing event's virtual
        clock (the popped entry of the completion-time vector) has passed
        the last flush time + deadline; the buffer is never empty here
        (this event's arrival is in it), so a deadline flush aggregates
        whatever the stragglers left behind."""
        st, A = ctx["state"], ctx["A"]
        comm = ctx["new_comm"]        # committed rows, incl. this arrival's

        def _merge(mb):
            return lambda n_, o: jnp.where(
                mb.reshape((M,) + (1,) * (o.ndim - 1)), n_, o)

        def flush(_):
            mask = jnp.isinf(A["next_done"]).astype(jnp.float32)
            mb = mask > 0
            new_ver = A["server_version"] + 1
            # next generation key schedule == the sync engine's next round
            k_loc, k_down, k_sel, k_up, k_next = jax.random.split(st.rng, 5)
            nbatch = data_fn(new_ver)
            if population is not None:
                # slot weights come from the clients the slots HOST (the
                # slot_size table recorded at their dispatch) — nbatch holds
                # the NEXT cohort's sizes, different clients entirely
                w = A["slot_size"] * mask
            else:
                # client dataset sizes are generation-invariant (seed-only
                # tables), so the next generation's batch also provides the
                # FedAvg weights for the flushing aggregation
                sizes = nbatch.get("sizes", jnp.ones((M,), jnp.float32))
                w = sizes * mask
            wsum = jnp.maximum(w.sum(), 1e-9)
            # the shared aggregation body: barrier + weighted mean, exactly
            # the sync wire's lowering (Dispatch.aggregate_rows)
            agg = dispatch.aggregate_rows(A["updates"], A["buf_w"] * w, wsum)
            # mean staleness of the flushed buffer -> staleness-scaled
            # server-optimizer moments (server_opt.apply, DESIGN.md §8);
            # 0 in the degenerate limit, where the scale is exactly 1
            tau_mean = ((mask * A["buf_tau"]).sum()
                        / jnp.maximum(mask.sum(), 1.0))
            new_params, new_sos = server_opt.apply(fl, st.params, agg,
                                                   st.server_opt_state,
                                                   staleness=tau_mean,
                                                   staleness_alpha=alpha)
            loss = (w * A["losses"]).sum() / wsum
            if population is not None:
                # flushed slots take on the freshly sampled cohort's
                # clients; still-in-flight slots keep theirs
                ids_new = population.cohort_ids(new_ver)
                ids_disp = jnp.where(mb, ids_new, A["slot_client"])
            if store is not None:
                rows_in, comm_out = store.gather(comm, ids_disp)
            else:
                rows_in, comm_out = comm, comm
            dec_rows, losses, pending = dispatch(new_params, nbatch, rows_in,
                                                 k_loc, k_down, k_up)
            lat = device_latency(profile, nbatch["resources"], k_sel)
            A3 = dict(
                A,
                updates=jax.tree.map(_merge(mb), dec_rows, A["updates"]),
                next_done=jnp.where(mb, ctx["clock"] + lat, A["next_done"]),
                version=jnp.where(mb, new_ver, A["version"]),
                buf_w=jnp.where(mb, 0.0, A["buf_w"]),
                buf_tau=jnp.where(mb, 0.0, A["buf_tau"]),
                losses=jnp.where(mb, losses, A["losses"]),
                server_version=new_ver,
                # adaptive arming (DESIGN.md §13): the next flush deadline
                # is the current completion-time quantile estimate, not a
                # fixed knob — the deadline tracks the stragglers
                next_deadline=(ctx["clock"] + A["q_est"] if adaptive
                               else (ctx["clock"] + jnp.float32(deadline)
                                     if deadline > 0
                                     else A["next_deadline"])),
            )
            if need_lat:
                A3["slot_lat"] = jnp.where(mb, lat, A["slot_lat"])
            if stateful:
                A3["pending_comm"] = tuple(
                    jax.tree.map(_merge(mb), pending[li],
                                 A["pending_comm"][li])
                    for li in range(len(pending)))
            if population is not None:
                A3["slot_client"] = ids_disp
                A3["slot_size"] = jnp.where(
                    mb, nbatch.get("sizes", jnp.ones((M,), jnp.float32)),
                    A["slot_size"])
            return (new_params, new_sos, A3, k_next, loss,
                    mask.sum(), jnp.float32(1.0), comm_out)

        def wait(_):
            return (st.params, st.server_opt_state, A, st.rng,
                    A["losses"].mean(), jnp.float32(0.0), jnp.float32(0.0),
                    comm)

        fire = ctx["fill"] >= K
        if deadline > 0 or adaptive:
            fire = fire | (ctx["clock"] >= A["next_deadline"])
        (params, sos, A3, rng, loss, n_down, flushed, comm_out) = \
            jax.lax.cond(fire, flush, wait, None)
        ctx.update(new_params=params, new_sos=sos, A=A3, new_rng=rng,
                   loss=loss, n_down=n_down, flushed=flushed,
                   new_comm=comm_out)
        return ctx

    def hop_ledger(ctx):
        # one upload per event; downlink bytes are paid at flush, once per
        # re-dispatched contributor
        ctx["ledger"] = CommLedger(
            uplink_wire=jnp.float32(terms["up_wire"]),
            uplink_entropy=jnp.float32(terms["up_entropy"]),
            downlink_wire=ctx["n_down"] * jnp.float32(terms["down_wire"]),
            uplink_dense=jnp.float32(terms["dense"]),
            downlink_dense=ctx["n_down"] * jnp.float32(terms["dense"]),
            virtual_time=ctx["clock"],
        )
        if terms.get("dp_rho", 0.0):
            # one client upload per event -> one round of zCDP spend
            ctx["ledger"] = dataclasses.replace(
                ctx["ledger"], dp_rho=jnp.float32(terms["dp_rho"]))
        return ctx

    def hop_telemetry(ctx):
        # flight recorder (repro.obs, DESIGN.md §12): per-event RoundStats —
        # one upload per event (up_unit=1 against the absolute per-event
        # ledger), this event's staleness as a one-hot histogram row, the
        # post-arrival buffer fill, and the arriving client's store outcome.
        # Reads already-computed values only; the off graph is identical.
        st = ctx["state"]
        if store is not None:
            ctrs = store.stats(
                st.comm_state, st.async_state["slot_client"][ctx["c"]][None])
        else:
            ctrs = None
        ctx["round_stats"] = obs_tel.round_stats(
            tele, ctx["ledger"], up_unit=jnp.float32(1.0),
            down_unit=ctx["n_down"],
            staleness=ctx["tau"].astype(jnp.float32),
            fill=ctx["fill"].astype(jnp.float32), store=ctrs,
            selected=jnp.float32(1.0), available=jnp.float32(M),
            dropped=ctx.get("scn_dropped"))
        return ctx

    def hop_finalize(ctx):
        st = ctx["state"]
        ctx["metrics"] = {
            "loss": ctx["loss"],
            "clock": ctx["clock"],
            "staleness": ctx["tau"].astype(jnp.float32),
            "server_version": ctx["A"]["server_version"],
            "buffer_fill": (ctx["fill"].astype(jnp.float32)
                            * (1.0 - ctx["flushed"])),
            "flushed": ctx["flushed"],
            "ledger": ctx["ledger"],
        }
        if adaptive:
            ctx["metrics"]["q_est"] = ctx["A"]["q_est"]
        if tele is not None:
            ctx["metrics"]["round_stats"] = ctx["round_stats"]
        ctx["new_state"] = FLState(
            params=ctx["new_params"], server_opt_state=ctx["new_sos"],
            control=None, client_controls=None,
            comm_state=ctx["new_comm"], rng=ctx["new_rng"],
            round=st.round + 1, async_state=ctx["A"],
        )
        return ctx

    hops = [("pop", hop_pop), ("arrive", hop_arrive),
            ("flush", hop_flush), ("ledger", hop_ledger)]
    if tele is not None:
        hops.append(("telemetry", hop_telemetry))
    hops.append(("finalize", hop_finalize))
    program = eng.RoundProgram(topology=topo, hops=tuple(hops))

    aux = {"buffer_size": K, "staleness_alpha": alpha,
           "latency_profile": profile, "flush_deadline": deadline,
           "events_per_generation": K}
    if population is not None:
        aux.update(population=population, cohort=M)
    if tele is not None:
        aux["telemetry"] = tele
    return eng.RoundEngine(
        topology=topo, program=program, round_fn=program,
        init_fn=init_fn, n_clients=C, terms=terms, aux=aux,
    )


# ---------------------------------------------------------------------------
# convenience binding (mirrors simulate.make_sim_step)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AsyncFL:
    init_fn: object
    step_fn: object        # jit'd (state, batch) -> (state, metrics): 1 event
    n_clients: int
    buffer_size: int
    terms: dict
    engine: object = None


def make_async_step(model: Model, fl: FLConfig, n_clients: int, data_fn,
                    buffer_size: int = 0, staleness_alpha: float = None,
                    latency_profile: str = None, flush_deadline: float = None,
                    chunk: int = 64) -> AsyncFL:
    """Build the async event step.  ``run_rounds(a.engine, state, data_fn,
    n_events)`` then drives ``n_events`` server events through the scan
    driver (the per-step batch the runner samples is unused by the async
    round_fn and dead-code-eliminated by XLA — the engine samples its own
    dispatch batches keyed on server version)."""
    from repro.core.engine import Topology, make_round_engine
    # sentinel knobs (None / "") fall back to the FLConfig fields inside
    # _async_knobs at build time
    topo = Topology.async_(n_clients, buffer_size=buffer_size,
                           staleness_alpha=staleness_alpha,
                           latency_profile=latency_profile or "",
                           flush_deadline=flush_deadline)
    engine = make_round_engine(model, fl, topo, chunk=chunk, data_fn=data_fn)
    return AsyncFL(init_fn=engine.init_fn, step_fn=jax.jit(engine.round_fn),
                   n_clients=engine.n_clients,
                   buffer_size=engine.aux["buffer_size"],
                   terms=engine.terms, engine=engine)
