"""Decentralized / peer-to-peer FL (survey §III.B.4).

No central server: every client keeps its own model (leading C dim over the
``data`` axis) and each round does local SGD followed by **gossip mixing**
with its ring neighbours via ``jax.lax.ppermute`` (-> ``collective-permute``
in HLO — the star-topology all-reduce is replaced by point-to-point edges,
exactly the survey's topology contrast, Fig. 7).

  * BrainTorrent [65] / P2P-FL [64]: uncompressed neighbour averaging.
  * QuanTimed-DSGD [61]: neighbours exchange *quantized* models
    (``compressor="qsgd8"``) — the wire carries int8.

Mixing matrix: symmetric ring  W = I/2 + (L+R)/4  (doubly stochastic), so the
iterates converge to consensus at the classic 1-λ₂(W) rate; the test suite
asserts the consensus contraction.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compress.api import make_compressor
from repro.core.types import FLConfig
from repro.models import sharding as shd
from repro.models.model import Model

from repro.core.compat import shard_map


@dataclasses.dataclass
class GossipStep:
    init_fn: Any
    step_fn: Any
    state_shardings: Any
    n_clients: int


def make_gossip_step(model: Model, fl: FLConfig, mesh: Mesh,
                     chunk: int = 512) -> GossipStep:
    cfg = model.cfg
    C = dict(mesh.shape)["data"]
    comp = make_compressor(fl.uplink_compressor, fraction=fl.topk_fraction,
                           block=fl.qsgd_block)

    pspecs = shd.tree_specs(model.abstract_params(), model.logical_axes(),
                            mesh, cfg.fsdp)
    cspecs = shd.with_prefix(pspecs, "data")

    fwd = [(i, (i + 1) % C) for i in range(C)]
    bwd = [(i, (i - 1) % C) for i in range(C)]

    def mix(params, rng):
        def body(ptree):
            out = []
            for li, leaf in enumerate(jax.tree.leaves(ptree)):
                flat = leaf.reshape(-1).astype(jnp.float32)
                r = jax.random.fold_in(rng, li)
                payload, _ = comp.encode(comp.init(flat.shape), r, flat)
                left = jax.lax.ppermute(payload, "data", fwd)
                right = jax.lax.ppermute(payload, "data", bwd)
                n = flat.shape[0]
                mixed = 0.5 * flat + 0.25 * (comp.decode(left, n)
                                             + comp.decode(right, n))
                out.append(mixed.reshape(leaf.shape).astype(leaf.dtype))
            return jax.tree.unflatten(jax.tree.structure(ptree), out)
        return shard_map(body, mesh=mesh, in_specs=(cspecs,),
                         out_specs=cspecs, check_vma=False)(params)

    def step_fn(state, batch):
        params, rng, rnd = state
        r_mix, r_next = jax.random.split(rng)

        def local(p_c, batch_c):
            loss, g = jax.value_and_grad(
                lambda p: model.loss(p, batch_c, chunk=chunk)[0])(p_c)
            p_c = jax.tree.map(
                lambda a, g_: (a.astype(jnp.float32)
                               - fl.local_lr * g_.astype(jnp.float32)
                               ).astype(a.dtype), p_c, g)
            return p_c, loss

        params, losses = jax.vmap(local)(params, batch)
        params = mix(params, r_mix)

        # consensus error (mean squared distance to the mean model)
        leaves = jax.tree.leaves(params)
        consensus = sum(
            jnp.sum((l.astype(jnp.float32)
                     - l.astype(jnp.float32).mean(0, keepdims=True)) ** 2)
            for l in leaves) / sum(l.size for l in leaves)
        return (params, r_next, rnd + 1), {"loss": losses.mean(),
                                           "consensus": consensus}

    def init_fn(rng):
        p = model.init(rng)
        # heterogeneous start: per-client perturbation (tests consensus)
        ps = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (C,) + a.shape), p)
        return (ps, jax.random.PRNGKey(fl.seed), jnp.zeros((), jnp.int32))

    state_specs = (cspecs, P(), P())
    return GossipStep(
        init_fn=init_fn,
        step_fn=step_fn,
        state_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s),
                                     state_specs,
                                     is_leaf=lambda x: isinstance(x, P)),
        n_clients=C,
    )
