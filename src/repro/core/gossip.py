"""Decentralized / peer-to-peer FL (survey §III.B.4) — the
``Topology.gossip`` binding of the RoundEngine.

No central server: every client keeps its own model (leading C dim over the
``data`` axis) and each round does local SGD followed by **gossip mixing**
with its ring neighbours via ``jax.lax.ppermute`` (-> ``collective-permute``
in HLO — the star-topology all-reduce is replaced by point-to-point edges,
exactly the survey's topology contrast, Fig. 7).

  * BrainTorrent [65] / P2P-FL [64]: uncompressed neighbour averaging.
  * QuanTimed-DSGD [61]: neighbours exchange *quantized* models
    (``compressor="qsgd8"``) — the wire carries int8.

The mix hop runs the full uplink CommPipeline *statefully*: biased
pipelines (top-k, STC, chained specs) gossip with error feedback — the
residual rides in ``FLState.comm_state`` with a leading C dim over ``data``
and never crosses the wire (DESIGN.md §5).

Mixing matrix: symmetric ring  W = I/2 + (L+R)/4  (doubly stochastic), so the
iterates converge to consensus at the classic 1-λ₂(W) rate; the test suite
asserts the consensus contraction. Custom graphs: ``Topology.gossip``
accepts ``(ring_offset, weight)`` edge tuples and full permutation tuples
(matchings) — ``engine.expander_graph`` / ``engine.erdos_renyi_graph`` (or
``Topology.gossip_expander`` / ``Topology.gossip_er``) build power-of-two
circulant expanders and Erdős–Rényi matching decompositions; every graph
passes a doubly-stochastic check at engine build time.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from jax.sharding import Mesh

from repro.core.engine import Topology, make_round_engine
from repro.core.types import FLConfig
from repro.models.model import Model


@dataclasses.dataclass
class GossipStep:
    init_fn: Any
    step_fn: Any
    state_shardings: Any
    n_clients: int
    terms: dict = None
    engine: Any = None      # the underlying RoundEngine (for run_rounds)


def make_gossip_step(model: Model, fl: FLConfig, mesh: Mesh,
                     chunk: int = 512) -> GossipStep:
    engine = make_round_engine(model, fl, Topology.gossip(), mesh=mesh,
                               chunk=chunk)
    return GossipStep(
        init_fn=engine.init_fn,
        step_fn=engine.round_fn,
        state_shardings=engine.state_shardings,
        n_clients=engine.n_clients,
        terms=engine.terms,
        engine=engine,
    )
