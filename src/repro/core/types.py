"""Core configuration and state dataclasses for the fedcomm framework.

Everything downstream (models, FL algorithms, launcher, dry-run) is driven by
three configs:

  * :class:`ArchConfig`  — one per assigned architecture (``repro/configs/``).
  * :class:`ShapeConfig` — one per assigned input shape (``configs/shapes.py``).
  * :class:`FLConfig`    — the paper's knobs: algorithm, compression, selection,
                           hierarchy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# Architecture
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A transformer-family architecture, expressive enough for all 10 assigned
    configs (dense / MoE / SSM / hybrid / enc-dec / VLM)."""

    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                    # 0 for attention-free archs
    num_kv_heads: int = 0
    d_ff: int = 0                     # dense FFN hidden (or per-expert hidden if MoE)
    vocab_size: int = 32000
    head_dim: int = 0                 # default: d_model // num_heads

    # --- MoE ---
    num_experts: int = 0              # 0 => dense FFN
    experts_per_token: int = 0
    expert_capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # 0 = full causal; >0 = window size
    # window applied only for the long-decode variant when the base arch is
    # full-attention; recorded per-run in the ledger/EXPERIMENTS.
    long_context_window: int = 8192

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0                # N (state size per head)
    ssm_expand: int = 2               # d_inner = expand * d_model
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256              # SSD chunk length

    # --- layer pattern (hybrid archs) ---
    # The model is scan(num_layers // len(block_pattern)) over one "super-block"
    # whose internal layers follow block_pattern, e.g. Jamba:
    #   ("mamba","mamba","mamba","attn","mamba","mamba","mamba","mamba")
    block_pattern: tuple = ("attn",)

    # --- encoder/decoder (audio) ---
    encoder_layers: int = 0           # >0 => enc-dec; encoder is bidirectional
    frontend_tokens: int = 0          # stub-frontend sequence length (mel frames /
                                      # image patches) fed as precomputed embeddings

    # --- VLM ---
    num_patches: int = 0              # patch-embedding prefix length

    # --- numerics / misc ---
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    tie_embeddings: bool = False
    remat: bool = True

    # --- distribution hints (see DESIGN.md §4) ---
    fsdp: bool = False                # shard params over the data axis too
                                      # (required for >~70B total params on v5e)
    client_axis: str = "data"         # "data" (cross-device FL, 16 clients/pod) or
                                      # "pod"  (cross-silo FL, 1 client per pod)

    citation: str = ""

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads and not self.num_kv_heads:
            object.__setattr__(self, "num_kv_heads", self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return all(b != "attn" for b in self.block_pattern) and not self.encoder_layers

    @property
    def num_superblocks(self) -> int:
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern length {len(self.block_pattern)}")
        return self.num_layers // len(self.block_pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ArchConfig":
        """The smoke-test variant of the same family (2 superblocks, small dims)."""
        pat = self.block_pattern
        small = dict(
            num_layers=2 * len(pat),
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            head_dim=0,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
            num_patches=min(self.num_patches, 8) if self.num_patches else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            dtype=jnp.float32,
            fsdp=False,
            client_axis="data",
            remat=False,
        )
        small.update(overrides)
        return dataclasses.replace(self, name=self.name + "-smoke", **small)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                         # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


# ---------------------------------------------------------------------------
# Federated-learning configuration (the paper's knobs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FLConfig:
    """Every communication-efficiency lever surveyed by the paper, composable."""

    # §III.B.1 local updating
    algorithm: str = "fedavg"         # fedavg|fedsgd|fedprox|scaffold|feddane
    local_steps: int = 1              # E; 1 => gradient-compression (FedSGD) mode
    local_lr: float = 0.05
    fedprox_mu: float = 0.0           # also FedDANE's proximal mu

    # §III.B.3 reduced updates: CMFL [35] update-relevance filtering — clients
    # whose delta sign-agrees with the previous global update less than the
    # threshold do not upload this round (0 = off). Simulation path.
    cmfl_threshold: float = 0.0

    # §III.B.5 compression — a CommPipeline spec: a legacy registry name
    # (none|qsgd8|qsgd4|topk|stc|sbc|sketch|hsq|randmask) or a chained spec
    # string like "topk:0.01>>qsgd:8" (DESIGN.md §3). STC *is*
    # "topk>>ternary"; DGC is "topk" + dgc_momentum.
    uplink_compressor: str = "none"
    downlink_compressor: str = "none" # none|lfl8 (LFL: quantized global broadcast)
    backend: str = "jax"              # encode/decode backend for every wire
                                      # hop: "jax" (pure) | "kernel" (Pallas;
                                      # per-stage "@kernel" suffixes in the
                                      # spec override — DESIGN.md §6)
    wire_format: str = "staged"       # payload format for every wire hop:
                                      # "staged" (storage-dtype buffers,
                                      # bit-exact with pre-packing engines) |
                                      # "packed" (bit-packed int codes on the
                                      # collective; per-stage "@fused"
                                      # suffixes override — DESIGN.md §10)
    topk_fraction: float = 0.01
    sketch_rows: int = 5
    sketch_cols: int = 4096
    qsgd_block: int = 2048            # per-block scale granularity
    error_feedback: bool = True       # wrap biased pipelines in error_feedback()
    # §Privacy (DESIGN.md §11) — the privacy-compatible wire stack. These
    # wrap the uplink spec exactly like the ">>secagg" / ">>dpnoise:s" spec
    # suffixes: dpnoise first (clip + Gaussian at the wire boundary), then
    # secagg (pairwise modular masks over the integer code planes; needs a
    # quantizing uplink spec), then EF/DGC outermost.
    secure_agg: bool = False          # mask the uplink's integer code planes
    dp_sigma: float = 0.0             # Gaussian noise multiplier (0 = off)
    dp_clip: float = 0.0              # per-leaf L2 clip (0 = no clipping;
                                      # required > 0 when dp_sigma > 0)
    dgc_momentum: float = 0.0         # >0: wrap in momentum_correction() (DGC)
    dgc_warmup_rounds: int = 0        # >0: DGC warm-up — the effective top-k
                                      # fraction anneals exponentially from
                                      # topk_fraction^(1/(W+1)) to
                                      # topk_fraction over W rounds

    # §III.B.2 client selection
    selection: str = "all"            # all | random | power_of_choice | multi_criteria
    clients_per_round: int = 0        # 0 => all
    # §III.B.3 reduced updates / hierarchy (FedPAQ periodic avg, Hier-Local-QSGD)
    hierarchical: bool = False        # edge agg every round, pod agg every sync_every
    sync_every: int = 4
    pod_compressor: str = "qsgd8"     # compressor for the cross-pod (cloud) hop

    # beyond-paper perf lever: dtype of the client delta pytree. The paper-
    # faithful baseline keeps f32 (what the sources' uncompressed FedAvg
    # sends); bf16 halves both the delta memory and the uncompressed
    # client-axis collective bytes (§Perf).
    delta_dtype: str = "f32"          # f32 | bf16

    # eval cadence for run_rounds: metrics_fn (the in-scan held-out eval)
    # runs only every eval_every-th round; skipped rounds carry the base
    # round metrics and NaN-fill the eval-only leaves (engine.RoundRunner)
    eval_every: int = 1

    # flight recorder (repro.obs, DESIGN.md §12): when True every round's
    # metrics additionally carry a fixed-shape RoundStats pytree (per-stage
    # wire byte attribution, staleness histogram, buffer occupancy, residual-
    # store counters, selection/availability counts) next to the CommLedger.
    # The telemetry hops only READ already-computed round values plus static
    # byte terms, so params / comm_state / ledger stay bit-exact and the
    # telemetry=False graph is the exact subgraph with the extra metric
    # leaves removed (proved differentially in tests/test_obs.py).
    telemetry: bool = False

    # §III.B asynchronous / semi-asynchronous updating (AsyncEngine,
    # DESIGN.md §7): the server consumes client completions in virtual-time
    # order and aggregates a FedBuff-style buffer of ``async_buffer_size``
    # updates (1 = FedAsync immediate application; 0 = full participation,
    # i.e. buffer_size == n_clients) with FedAsync staleness decay
    # ``(1 + tau)^(-staleness_alpha)``. ``latency_profile`` maps the FedMCCS
    # device resource profiles onto per-dispatch virtual latencies
    # (``data.pipeline.device_latency``): constant | resource | uniform |
    # heavy_tail. ``async_flush_deadline`` > 0 additionally flushes the
    # (always non-empty after an arrival) buffer whenever the virtual clock
    # passes the last flush time + deadline — adaptive buffer sizing: under
    # heavy-tail stragglers the server stops waiting for the K-th upload
    # once the deadline lapses (DESIGN.md §8). 0 = count-only FedBuff.
    async_buffer_size: int = 0
    staleness_alpha: float = 0.5
    latency_profile: str = "constant"
    async_flush_deadline: float = 0.0

    # server optimizer (beyond-paper: FedOpt family, Reddi et al. 2020).
    # On the async topology the adaptive members are staleness-aware: the
    # moment innovations are scaled by (1 + tau)^(-staleness_alpha) with
    # tau = the flushed buffer's mean staleness (server_opt.apply,
    # DESIGN.md §8); synchronous topologies pass tau = 0 (scale 1, the
    # classical FedOpt update).
    server_opt: str = "fedavg"        # fedavg | fedavgm | fedadam | fedyogi
    server_lr: float = 1.0
    server_beta1: float = 0.9
    server_beta2: float = 0.99
    server_eps: float = 1e-3

    # scenario pack (core.scenario, DESIGN.md §13): realistic client
    # dynamics behind mask-based static-shape semantics.  Every default
    # encodes "off" — Scenario.from_fl(FLConfig()).enabled is False and the
    # engines build today's exact graphs (the differential conformance
    # contract, tests/test_scenario.py).  ``scenario_trace`` picks the
    # availability schedule (static = i.i.d. Bernoulli, diurnal =
    # sinusoid-modulated, square = phase-shifted duty windows) with
    # ``scenario_period`` rounds per cycle; ``scenario_availability`` is
    # the duty-cycle rate on the dense sim/star path (a ClientPopulation
    # keeps its own ``availability`` rate and only borrows the trace
    # shape).  ``scenario_dropout`` is the mid-round dropout hazard per
    # unit virtual time (partial-update semantics: dropped clients become
    # zero-weight aggregate rows).  ``scenario_epoch_scale`` > 0 floors
    # the FedMCCS per-client local-epoch scale (stragglers run fewer local
    # steps).  ``scenario_deadline_quantile`` > 0 arms the async flush
    # deadline adaptively from a completion-time quantile tracker.
    scenario_trace: str = "static"
    scenario_period: float = 24.0
    scenario_availability: float = 1.0
    scenario_dropout: float = 0.0
    scenario_epoch_scale: float = 0.0
    scenario_deadline_quantile: float = 0.0
    scenario_seed: int = 0

    seed: int = 0


# ---------------------------------------------------------------------------
# Train / serve state
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FLState:
    """Server-side state threaded through ``train_step``."""
    params: PyTree
    server_opt_state: PyTree
    control: PyTree | None            # SCAFFOLD global control variate c
    client_controls: PyTree | None    # SCAFFOLD per-client c_i   (C leading dim)
    comm_state: PyTree | None         # CommPipeline state (EF residual, DGC
                                      # momentum, ...) — dense: tuple over
                                      # param leaves, C leading dim on every
                                      # array; ClientPopulation builds: the
                                      # bounded ResidualStore dict (slab /
                                      # client / stamp / clock [/ tail]),
                                      # capacity-led (DESIGN.md §9)
    rng: jax.Array
    round: jax.Array                  # int32 scalar
    prev_delta: PyTree | None = None  # CMFL relevance reference (last global
                                      # update); None unless cmfl enabled
    async_state: PyTree | None = None # AsyncEngine virtual-clock state (dict:
                                      # clock, next_done, version,
                                      # server_version, updates, buf_w,
                                      # losses, client and upload rng keys);
                                      # None on synchronous topologies


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CommLedger:
    """Per-round communication accounting (the survey's core metric).

    ``*_wire`` counts the bytes our dtype-packed payloads actually occupy;
    ``*_entropy`` counts the bytes the source papers' entropy coders (Golomb /
    Elias) would achieve for the same payload (see DESIGN.md §1).
    All values are float32 scalars so they jit cleanly.
    """
    uplink_wire: jax.Array
    uplink_entropy: jax.Array
    downlink_wire: jax.Array
    uplink_dense: jax.Array           # what uncompressed f32 would have cost
    downlink_dense: jax.Array
    virtual_time: Any = None          # AsyncEngine virtual wall-clock at this
                                      # event (f32 seconds); None on
                                      # synchronous topologies — lets
                                      # bytes-to-target and time-to-target
                                      # read off the same ledger stack
    dp_rho: Any = None                # zCDP privacy spend this round (f32,
                                      # summed over participating clients);
                                      # None unless a dpnoise stage is in the
                                      # uplink.  zCDP composes additively, so
                                      # the ledger accumulation that sums
                                      # bytes sums the privacy budget too
                                      # (DESIGN.md §11)

    @staticmethod
    def zero() -> "CommLedger":
        z = jnp.zeros((), jnp.float32)
        return CommLedger(z, z, z, z, z)

    def __add__(self, other: "CommLedger") -> "CommLedger":
        return jax.tree.map(lambda a, b: a + b, self, other)

    def compression_ratio(self) -> jax.Array:
        total = self.uplink_wire + self.downlink_wire
        dense = self.uplink_dense + self.downlink_dense
        return dense / jnp.maximum(total, 1.0)
