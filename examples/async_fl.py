"""Asynchronous FL quickstart: FedBuff on a virtual clock in ~40 lines.

    PYTHONPATH=src python examples/async_fl.py \
        [--buffer-size 4] [--alpha 0.5] [--profile heavy_tail] \
        [--flush-deadline 0] [--generations 10] [--clients 8]

Runs the AsyncEngine (DESIGN.md §7) on the paper-faithful small LM: each
client slot draws a per-dispatch latency from its simulated device profile,
the server consumes completions in virtual-time order, and a FedBuff buffer
of K updates flushes with FedAsync staleness decay ``(1+tau)^(-alpha)``.
One table row per server event; ``--generations G`` runs ``G * clients``
events (the upload budget of G synchronous rounds).

``--buffer-size 0 --profile constant`` is the degenerate limit that
reproduces synchronous FedAvg bit-exactly (tests/test_async.py).
"""
import argparse

import jax

from repro.configs.registry import get_arch
from repro.core.async_engine import make_async_step
from repro.core.engine import run_rounds
from repro.core.types import FLConfig
from repro.data.synthetic import FedDataConfig, sample_round
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--compressor", default="qsgd8")
    ap.add_argument("--buffer-size", type=int, default=4,
                    help="FedBuff K (1 = FedAsync, 0 = clients = sync limit)")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--flush-deadline", type=float, default=0.0,
                    help="also flush when the virtual clock passes the last "
                         "flush + deadline (adaptive buffer sizing, "
                         "DESIGN.md §8; 0 = count-only FedBuff)")
    ap.add_argument("--profile", default="heavy_tail",
                    choices=["constant", "resource", "uniform", "heavy_tail"])
    ap.add_argument("--generations", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch("paper_lm")
    model = Model(cfg)
    fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                  uplink_compressor=args.compressor)
    data = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=args.clients,
                         seq_len=48, batch_per_client=4, heterogeneity=2.0)

    def data_fn(v):
        return sample_round(data, jax.random.fold_in(jax.random.PRNGKey(1), v))

    a = make_async_step(model, fl, args.clients, data_fn,
                        buffer_size=args.buffer_size,
                        staleness_alpha=args.alpha,
                        latency_profile=args.profile,
                        flush_deadline=args.flush_deadline, chunk=48)
    n_events = args.generations * args.clients
    print(f"params={model.param_count():,} K={a.buffer_size} "
          f"alpha={args.alpha} profile={args.profile} "
          f"deadline={args.flush_deadline or 'off'} events={n_events}")

    state = a.init_fn(jax.random.PRNGKey(0))
    state, ms = run_rounds(a.engine, state, data_fn, n_events, chunk=8)

    print(f"{'event':>5} {'vclock':>8} {'ver':>4} {'tau':>4} "
          f"{'fill':>4} {'loss':>7} {'cumMB':>8}")
    cum = 0.0
    for e in range(n_events):
        led = jax.tree.map(lambda x, e=e: x[e], ms["ledger"])
        cum += float(led.uplink_wire + led.downlink_wire)
        if float(ms["flushed"][e]) or e == n_events - 1:
            print(f"{e:>5} {float(ms['clock'][e]):>8.2f} "
                  f"{int(ms['server_version'][e]):>4} "
                  f"{float(ms['staleness'][e]):>4.0f} "
                  f"{float(ms['buffer_fill'][e]):>4.0f} "
                  f"{float(ms['loss'][e]):>7.3f} {cum/1e6:>8.2f}")
    print(f"final: virtual_time={float(ms['clock'][-1]):.2f} "
          f"server_versions={int(ms['server_version'][-1])} "
          f"mean_staleness={float(jax.numpy.mean(ms['staleness'])):.2f}")


if __name__ == "__main__":
    main()
