"""Reproduce the survey's central figure: accuracy vs cumulative bytes for
every compression family, on the same non-iid federated LM task.

    PYTHONPATH=src python examples/compare_compressors.py --rounds 30 [--grid]

Prints an aligned table plus an ASCII loss-vs-MB plot. ``--grid`` adds the
combined-scheme sweep (topk fraction x qsgd bits, plus sketch>>qsgd) so the
Pareto points per budget can be read off. Each run is one RoundEngine scan
(``run_rounds``) with the held-out eval compiled into the scan body.
"""
import argparse

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.core.engine import run_rounds
from repro.core.simulate import make_sim_step
from repro.core.types import FLConfig
from repro.data.synthetic import FedDataConfig, eval_batch, sample_round
from repro.models.model import Model

METHODS = {
    "dense_f32": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2),
    "qsgd8": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                      uplink_compressor="qsgd8"),
    "qsgd8+lfl8": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                           uplink_compressor="qsgd8",
                           downlink_compressor="lfl8"),
    "stc_1%": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                       uplink_compressor="stc", topk_fraction=0.01),
    "topk_1%": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                        uplink_compressor="topk", topk_fraction=0.01),
    "sbc_1%": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                       uplink_compressor="sbc", topk_fraction=0.01),
    "sketch": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.1,
                       uplink_compressor="sketch"),
    # combined schemes — one-line CommPipeline spec strings
    "topk5%>>qsgd8": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                              uplink_compressor="topk:0.05>>qsgd:8"),
    "dgc_1%": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                       uplink_compressor="topk", topk_fraction=0.01,
                       dgc_momentum=0.9),
    # DGC warm-up: effective fraction anneals 0.01^((r+1)/5): ~40% -> 1%
    "dgc_1%_warmup": FLConfig(algorithm="fedavg", local_steps=2,
                              local_lr=0.2, uplink_compressor="topk",
                              topk_fraction=0.01, dgc_momentum=0.9,
                              dgc_warmup_rounds=4),
}

# the combined-scheme sweep (--grid): quantised-sparse grid + sketch>>qsgd
GRID = {
    f"topk{f:g}>>qsgd{b}": FLConfig(
        algorithm="fedavg", local_steps=2, local_lr=0.2,
        uplink_compressor=f"topk:{f:g}>>qsgd:{b}")
    for f in (0.01, 0.05, 0.25) for b in (4, 8)
}
GRID["sketch>>qsgd8"] = FLConfig(algorithm="fedavg", local_steps=2,
                                 local_lr=0.1,
                                 uplink_compressor="sketch>>qsgd:8")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--grid", action="store_true",
                    help="add the combined-scheme topk x qsgd sweep")
    args = ap.parse_args()

    cfg = get_arch("paper_lm")
    model = Model(cfg)
    data = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=8,
                         seq_len=48, batch_per_client=4, heterogeneity=2.0)
    ev = eval_batch(data, jax.random.PRNGKey(99), batch_size=8)
    data_fn = lambda r: sample_round(
        data, jax.random.fold_in(jax.random.PRNGKey(1), r))
    metrics_fn = lambda st, m: dict(
        m, eval_loss=model.loss(st.params, ev, chunk=48)[0])

    methods = dict(METHODS)
    if args.grid:
        methods.update(GRID)
    results = {}
    for name, fl in methods.items():
        sim = make_sim_step(model, fl, 8, chunk=48)
        state = sim.init_fn(jax.random.PRNGKey(0))
        state, ms = run_rounds(sim.engine, state, data_fn, args.rounds,
                               chunk=8, metrics_fn=metrics_fn)
        mb = np.cumsum(np.asarray(ms["ledger"].uplink_wire, np.float64)
                       + np.asarray(ms["ledger"].downlink_wire,
                                    np.float64)) / 1e6
        curve = list(zip(mb, [float(x) for x in ms["eval_loss"]]))
        results[name] = curve
        print(f"{name:>14}: final eval {curve[-1][1]:.3f} "
              f"after {curve[-1][0]:8.2f} MB", flush=True)

    print("\nloss vs cumulative MB (log-ish buckets)")
    header = f"{'MB<=':>8}" + "".join(f"{n:>15}" for n in results)
    print(header)
    for budget in (1, 3, 10, 30, 100, 300, 1000):
        row = f"{budget:>8}"
        for name, curve in results.items():
            best = min((l for mb, l in curve if mb <= budget),
                       default=float("nan"))
            row += f"{best:>15.3f}"
        print(row)

    # bytes to the common target loss — the Pareto read-out
    target = max(c[-1][1] for c in results.values()) + 0.02
    print(f"\nMB to reach loss<={target:.3f} (Pareto points)")
    for name, curve in sorted(
            results.items(),
            key=lambda kv: next((mb for mb, l in kv[1] if l <= target),
                                float("inf"))):
        mb = next((mb for mb, l in curve if l <= target), None)
        print(f"{name:>14}: {'%8.2f' % mb if mb is not None else '   never'}")


if __name__ == "__main__":
    main()
