"""Reproduce the survey's central figure: accuracy vs cumulative bytes for
every compression family, on the same non-iid federated LM task.

    PYTHONPATH=src python examples/compare_compressors.py --rounds 30

Prints an aligned table plus an ASCII loss-vs-MB plot.
"""
import argparse

import jax

from repro.configs.registry import get_arch
from repro.core.simulate import make_sim_step
from repro.core.types import FLConfig
from repro.data.synthetic import FedDataConfig, eval_batch, sample_round
from repro.models.model import Model

METHODS = {
    "dense_f32": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2),
    "qsgd8": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                      uplink_compressor="qsgd8"),
    "qsgd8+lfl8": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                           uplink_compressor="qsgd8",
                           downlink_compressor="lfl8"),
    "stc_1%": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                       uplink_compressor="stc", topk_fraction=0.01),
    "topk_1%": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                        uplink_compressor="topk", topk_fraction=0.01),
    "sbc_1%": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                       uplink_compressor="sbc", topk_fraction=0.01),
    "sketch": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.1,
                       uplink_compressor="sketch"),
    # combined schemes — one-line CommPipeline spec strings
    "topk5%>>qsgd8": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                              uplink_compressor="topk:0.05>>qsgd:8"),
    "dgc_1%": FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                       uplink_compressor="topk", topk_fraction=0.01,
                       dgc_momentum=0.9),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    args = ap.parse_args()

    cfg = get_arch("paper_lm")
    model = Model(cfg)
    data = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=8,
                         seq_len=48, batch_per_client=4, heterogeneity=2.0)
    ev = eval_batch(data, jax.random.PRNGKey(99), batch_size=8)
    evl = jax.jit(lambda p: model.loss(p, ev, chunk=48)[0])

    results = {}
    for name, fl in METHODS.items():
        sim = make_sim_step(model, fl, 8, chunk=48)
        state = sim.init_fn(jax.random.PRNGKey(0))
        cum, curve = 0.0, []
        for r in range(args.rounds):
            b = sample_round(data, jax.random.fold_in(jax.random.PRNGKey(1), r))
            state, m = sim.step_fn(state, b)
            cum += float(m["ledger"].uplink_wire + m["ledger"].downlink_wire)
            curve.append((cum / 1e6, float(evl(state.params))))
        results[name] = curve
        print(f"{name:>12}: final eval {curve[-1][1]:.3f} "
              f"after {curve[-1][0]:8.2f} MB", flush=True)

    print("\nloss vs cumulative MB (log-ish buckets)")
    header = f"{'MB<=':>8}" + "".join(f"{n:>12}" for n in results)
    print(header)
    for budget in (1, 3, 10, 30, 100, 300, 1000):
        row = f"{budget:>8}"
        for name, curve in results.items():
            best = min((l for mb, l in curve if mb <= budget),
                       default=float("nan"))
            row += f"{best:>12.3f}"
        print(row)


if __name__ == "__main__":
    main()
