"""Hierarchical (client -> edge/pod -> cloud) FL on a multi-pod host mesh —
the Hier-Local-QSGD / FedPAQ periodic-averaging demo.

    PYTHONPATH=src python examples/hierarchical_multipod.py --sync-every 4

Runs on 8 virtual host devices as a (2 pods x 2 clients x 2 TP) mesh; shows
per-round pod divergence growing between cloud syncs and collapsing to zero
at each sync, plus the edge-vs-cloud wire-byte split.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse                                              # noqa: E402

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402

from repro.core.engine import run_rounds                     # noqa: E402
from repro.core.hierarchical import make_hier_fl_train_step  # noqa: E402
from repro.core.types import ArchConfig, FLConfig            # noqa: E402
from repro.data.synthetic import FedDataConfig, sample_round # noqa: E402
from repro.models.model import Model                         # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=12)
    args = ap.parse_args()

    from repro.core.compat import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = ArchConfig(name="hier-demo", family="dense", num_layers=2,
                     d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                     vocab_size=256, block_pattern=("attn+mlp",),
                     dtype=jnp.float32, remat=False)
    model = Model(cfg)
    fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                  uplink_compressor="qsgd8", pod_compressor="qsgd8",
                  hierarchical=True, sync_every=args.sync_every)
    h = make_hier_fl_train_step(model, fl, mesh, chunk=32)
    state = h.init_fn(jax.random.PRNGKey(0))

    data = FedDataConfig(vocab_size=256, num_clients=4, seq_len=32,
                         batch_per_client=4, heterogeneity=2.0)

    def data_fn(r):
        b = sample_round(data, jax.random.fold_in(jax.random.PRNGKey(1), r))
        return {k: v.reshape((2, 2) + v.shape[1:]) for k, v in b.items()
                if k in ("tokens", "labels", "mask")}

    print(f"mesh={dict(mesh.shape)} params={model.param_count():,} "
          f"sync_every={args.sync_every}")
    # one scan-compiled driver: the engine's round_fn folds the edge/cloud
    # alternation into the compiled program (cond on round % sync_every)
    state, ms = run_rounds(h.engine, state, data_fn, args.rounds, chunk=8)
    print(f"{'round':>5} {'kind':>6} {'loss':>7} {'pod_div':>10} {'wireMB':>8}")
    for r in range(args.rounds):
        cloud = (r + 1) % args.sync_every == 0
        print(f"{r:>5} {'cloud' if cloud else 'edge':>6} "
              f"{float(ms['loss'][r]):>7.3f} "
              f"{float(ms['pod_divergence'][r]):>10.2e} "
              f"{float(ms['ledger'].uplink_wire[r])/1e6:>8.3f}")
    print("\npod divergence grows between syncs, resets at cloud rounds;")
    print("cloud rounds pay the extra (quantised) DCN hop — that factor of")
    print(f"{args.sync_every}x fewer cloud syncs is Hier-Local-QSGD's saving.")


if __name__ == "__main__":
    main()
