"""Quickstart: federated training with communication compression in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py [--compressor qsgd8]

Trains the paper-faithful small LM over 8 non-iid synthetic clients with the
chosen uplink compressor and prints loss + communication-ledger columns —
the survey's accuracy-vs-bytes trade-off, live. Rounds run through the
RoundEngine scan driver (``run_rounds``): data sampling and the held-out
eval are compiled into the scan, one dispatch per chunk of rounds.
"""
import argparse

import jax

from repro.configs.registry import get_arch
from repro.core.engine import run_rounds
from repro.core.simulate import make_sim_step
from repro.core.types import FLConfig
from repro.data.synthetic import FedDataConfig, eval_batch, sample_round
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--compressor", default="qsgd8",
                    help="registry name (none|qsgd8|qsgd4|topk|stc|sbc|sketch"
                         "|hsq|randmask) or a pipeline spec like "
                         "'topk:0.01>>qsgd:8' (DESIGN.md §3)")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch("paper_lm")
    model = Model(cfg)
    fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                  uplink_compressor=args.compressor, topk_fraction=0.01)
    data = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=args.clients,
                         seq_len=48, batch_per_client=4, heterogeneity=2.0)

    sim = make_sim_step(model, fl, args.clients, chunk=48)
    state = sim.init_fn(jax.random.PRNGKey(0))
    ev = eval_batch(data, jax.random.PRNGKey(99), batch_size=8)

    data_fn = lambda r: sample_round(
        data, jax.random.fold_in(jax.random.PRNGKey(1), r))
    metrics_fn = lambda st, m: dict(
        m, eval_loss=model.loss(st.params, ev, chunk=48)[0])

    print(f"params={model.param_count():,}  compressor={args.compressor}")
    state, ms = run_rounds(sim.engine, state, data_fn, args.rounds,
                           chunk=8, metrics_fn=metrics_fn)

    print(f"{'round':>5} {'train':>7} {'eval':>7} {'upMB':>8} {'ratio':>6}")
    cum = 0.0
    for r in range(args.rounds):
        led = jax.tree.map(lambda x, r=r: x[r], ms["ledger"])
        cum += float(led.uplink_wire + led.downlink_wire)
        if r % 2 == 1:
            print(f"{r:>5} {float(ms['loss'][r]):>7.3f} "
                  f"{float(ms['eval_loss'][r]):>7.3f} {cum/1e6:>8.2f} "
                  f"{float(led.compression_ratio()):>6.1f}x")


if __name__ == "__main__":
    main()
