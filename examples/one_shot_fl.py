"""One-shot federated learning [58]: a SINGLE communication round.

Each client trains its model to (local) completion; the server averages the
models once. Compare against multi-round FedAvg at the same total byte
budget — the survey's §III.B.3 'reduce model updates' extreme point.

    PYTHONPATH=src python examples/one_shot_fl.py
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core.engine import run_rounds
from repro.core.simulate import make_sim_step
from repro.core.types import FLConfig
from repro.data.synthetic import FedDataConfig, eval_batch, sample_round
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--local-steps", type=int, default=40)
    ap.add_argument("--clients", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch("paper_lm")
    model = Model(cfg)
    data = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=args.clients,
                         seq_len=48, batch_per_client=4, heterogeneity=1.5)
    ev = eval_batch(data, jax.random.PRNGKey(99), batch_size=8)
    evl = jax.jit(lambda p: model.loss(p, ev, chunk=48)[0])
    dense_mb = model.param_count() * 4 / 1e6

    # --- one-shot: E=local_steps local epochs, ONE round -------------------
    fl1 = FLConfig(algorithm="fedavg", local_steps=args.local_steps,
                   local_lr=0.1)
    sim1 = make_sim_step(model, fl1, args.clients, chunk=48)
    s1 = sim1.init_fn(jax.random.PRNGKey(0))
    b = sample_round(data, jax.random.PRNGKey(1))
    s1, m1 = sim1.step_fn(s1, b)
    one_shot_loss = float(evl(s1.params))
    one_shot_mb = float(m1["ledger"].uplink_wire) / 1e6
    print(f"one-shot ({args.local_steps} local steps, 1 round): "
          f"eval={one_shot_loss:.3f}  uplink={one_shot_mb:.2f}MB")

    # --- FedAvg with the same number of gradient steps spread over rounds --
    # (one scan-compiled run_rounds call — the multi-round driver)
    rounds = max(1, args.local_steps // 4)
    fl2 = FLConfig(algorithm="fedavg", local_steps=4, local_lr=0.1)
    sim2 = make_sim_step(model, fl2, args.clients, chunk=48)
    s2 = sim2.init_fn(jax.random.PRNGKey(0))
    s2, ms = run_rounds(
        sim2.engine, s2,
        lambda r: sample_round(data,
                               jax.random.fold_in(jax.random.PRNGKey(1), r)),
        rounds, chunk=min(8, rounds))
    mb2 = float(ms["ledger"].uplink_wire.sum()) / 1e6
    multi_loss = float(evl(s2.params))
    print(f"fedavg   ({rounds} rounds x 4 local steps):    "
          f"eval={multi_loss:.3f}  uplink={mb2:.2f}MB")
    print(f"\none-shot uses {mb2/one_shot_mb:.0f}x fewer bytes; "
          f"accuracy gap {one_shot_loss - multi_loss:+.3f} nats — the "
          f"trade-off [58] documents.")


if __name__ == "__main__":
    main()
