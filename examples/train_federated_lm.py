"""End-to-end federated LM training driver (deliverable b).

    PYTHONPATH=src python examples/train_federated_lm.py \
        --preset 100m --rounds 300 --compressor stc --checkpoint ckpt.npz

Presets scale the same llama-style family from CPU-friendly (~4M) to the
~100M model the assignment's end-to-end driver calls for — the 100m preset
trains a 12L/768d model for a few hundred rounds (hours on this 1-core CPU
container; minutes on a real slice). Evaluation, the communication ledger,
and npz checkpointing are all exercised. Resume with --restore.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.core.engine import RoundRunner
from repro.core.simulate import make_sim_step
from repro.core.types import ArchConfig, FLConfig
from repro.data.synthetic import FedDataConfig, eval_batch, sample_round
from repro.models.model import Model

PRESETS = {
    "4m": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
               d_ff=1024),
    "25m": dict(num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
                d_ff=2048),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=3072),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="4m", choices=list(PRESETS))
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--compressor", default="qsgd8")
    ap.add_argument("--algorithm", default="fedavg",
                    choices=["fedavg", "fedsgd", "fedprox", "scaffold"])
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--restore", default="")
    ap.add_argument("--eval-every", type=int, default=10)
    args = ap.parse_args()

    cfg = ArchConfig(name=f"fed-lm-{args.preset}", family="dense",
                     vocab_size=4096, block_pattern=("attn+mlp",),
                     dtype=jnp.float32, remat=False, **PRESETS[args.preset])
    model = Model(cfg)
    fl = FLConfig(algorithm=args.algorithm, local_steps=args.local_steps,
                  local_lr=0.1, uplink_compressor=args.compressor,
                  fedprox_mu=0.01 if args.algorithm == "fedprox" else 0.0)
    data = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=args.clients,
                         seq_len=args.seq, batch_per_client=4,
                         heterogeneity=1.5)

    sim = make_sim_step(model, fl, args.clients, chunk=min(args.seq, 128))
    state = sim.init_fn(jax.random.PRNGKey(0))
    if args.restore:
        state.params = checkpoint.restore(args.restore, state.params)
        print(f"restored {args.restore}")

    ev = eval_batch(data, jax.random.PRNGKey(99), batch_size=4)
    evl = jax.jit(lambda p: model.loss(p, ev, chunk=min(args.seq, 128))[0])

    print(f"model={cfg.name} params={model.param_count():,} "
          f"clients={args.clients} E={fl.local_steps} "
          f"compressor={args.compressor}")
    # rounds run through the RoundEngine scan driver — one runner for the
    # whole run, so the compiled chunk scan is reused across eval windows;
    # eval + checkpoint happen at window boundaries
    data_fn = lambda r: sample_round(
        data, jax.random.fold_in(jax.random.PRNGKey(1), r))
    runner = RoundRunner(sim.engine, data_fn, chunk=8)
    cum, t0, done = 0.0, time.time(), 0
    while done < args.rounds:
        k = min(args.eval_every, args.rounds - done)
        state, ms = runner.run(state, k)
        cum += float(ms["ledger"].uplink_wire.sum()
                     + ms["ledger"].downlink_wire.sum())
        done += k
        el = float(evl(state.params))
        dt = time.time() - t0
        print(f"round {done:>4}  train={float(ms['loss'][-1]):.3f} "
              f"eval={el:.3f}  comm={cum/1e6:,.1f}MB  "
              f"({dt/done:.2f}s/round)", flush=True)
        if args.checkpoint:
            checkpoint.save(args.checkpoint, state.params)
    if args.checkpoint:
        print(f"saved {args.checkpoint}")


if __name__ == "__main__":
    main()
