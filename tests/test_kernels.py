"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.qsgd import qsgd_quantize_blocked
from repro.kernels.ternary import ternarize_blocked
from repro.kernels.topk_mask import threshold_sparsify_blocked
from repro.kernels.count_sketch import count_sketch, CHUNK
from repro.compress.sketch import hash_params

SHAPES = [(8, 256), (16, 512), (8, 2048), (32, 128)]


@pytest.mark.parametrize("nb,block", SHAPES)
@pytest.mark.parametrize("bits", [4, 8])
def test_qsgd_kernel_matches_ref(nb, block, bits):
    k1, k2 = jax.random.split(jax.random.PRNGKey(nb * block + bits))
    xb = jax.random.normal(k1, (nb, block), jnp.float32) * 3.0
    u = jax.random.uniform(k2, (nb, block), jnp.float32)
    q, s = qsgd_quantize_blocked(xb, u, bits=bits, interpret=True)
    qr, sr = ref.ref_qsgd_quantize_blocked(xb, u, bits)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


@pytest.mark.parametrize("nb,block", SHAPES)
def test_ternary_kernel_matches_ref(nb, block):
    xb = jax.random.normal(jax.random.PRNGKey(0), (nb, block), jnp.float32)
    t = jnp.float32(0.8)
    code, psum, pcnt = ternarize_blocked(xb, t, interpret=True)
    cr, pr, cr2 = ref.ref_ternarize_blocked(xb, t)
    np.testing.assert_array_equal(np.asarray(code), np.asarray(cr))
    np.testing.assert_allclose(np.asarray(psum), np.asarray(pr), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(pcnt), np.asarray(cr2))


@pytest.mark.parametrize("nb,block", SHAPES)
def test_threshold_sparsify_matches_ref(nb, block):
    xb = jax.random.normal(jax.random.PRNGKey(1), (nb, block), jnp.float32)
    t = jnp.float32(1.1)
    kept, resid = threshold_sparsify_blocked(xb, t, interpret=True)
    kr, rr = ref.ref_threshold_sparsify_blocked(xb, t)
    np.testing.assert_allclose(np.asarray(kept), np.asarray(kr))
    np.testing.assert_allclose(np.asarray(resid), np.asarray(rr))
    # fusion invariant: kept + resid == x exactly
    np.testing.assert_allclose(np.asarray(kept + resid), np.asarray(xb))


@pytest.mark.parametrize("n", [CHUNK, 2 * CHUNK, 4 * CHUNK])
@pytest.mark.parametrize("rows,cols", [(3, 256), (5, 512)])
def test_count_sketch_kernel_matches_ref(n, rows, cols):
    x = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32)
    a, b = hash_params(rows)
    S = count_sketch(x, a, b, rows, cols, interpret=True)
    Sr = ref.ref_count_sketch(x, a, b, rows, cols)
    np.testing.assert_allclose(np.asarray(S), np.asarray(Sr),
                               rtol=1e-4, atol=1e-4)


def test_ops_wrappers_flat_interface():
    x = jax.random.normal(jax.random.PRNGKey(3), (5000,), jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(4), (5000,))
    q, s = ops.qsgd_quantize(x, u, bits=8, block=512)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    code, mu = ops.stc_ternarize(x, 0.05, block=512)
    assert code.shape == (5000,)
    k = int(round(5000 * 0.05))
    assert int((code != 0).sum()) >= k  # ties can exceed k, never fewer
    kept, resid = ops.threshold_sparsify(x, jnp.float32(1.0), block=512)
    np.testing.assert_allclose(np.asarray(kept + resid), np.asarray(x))
    S = ops.sketch(x, rows=3, cols=256)
    assert S.shape == (3, 256)


def test_sketch_kernel_heavy_hitters_roundtrip():
    """End-to-end: kernel-sketched vector recovers its heavy hitters."""
    from repro.compress.sketch import unsketch
    n = 4 * CHUNK
    x = jnp.zeros((n,)).at[jnp.array([3, 900, 2048])].set(
        jnp.array([10.0, -7.0, 12.0]))
    S = ops.sketch(x, rows=5, cols=1024)
    est = unsketch(S, n)
    np.testing.assert_allclose(np.asarray(est[jnp.array([3, 900, 2048])]),
                               [10.0, -7.0, 12.0], atol=1e-3)
