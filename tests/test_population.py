"""ClientPopulation + ResidualStore tests (DESIGN.md §9).

The anchor is the degenerate contract: with ``cohort == n_clients`` and
``capacity >= n_clients`` the streaming-population path must reproduce the
dense sim/async engines **bit-for-bit** — params AND comm_state — including
through ``@kernel`` compressor chains.  Around that: LRU-slab unit tests,
count-sketch tail fold/recover (and its energy-conservation guarantee, the
property that keeps the recover -> EF -> re-fold cycle from amplifying),
sampler properties, and the dense-build guard rails.

Fuzzed properties use ``hypothesis`` when installed and degrade to a
fixed-seed parametrized sweep otherwise (same pattern as test_compressors).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401 — probe only; see `fuzz` below
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.compress.residual_store import (ResidualStore, store_nbytes)
from repro.configs.registry import get_arch
from repro.core.engine import (POPULATION_DENSE_LIMIT, Topology,
                               make_round_engine, run_rounds,
                               uplink_pipeline)
from repro.core.population import ClientPopulation, _coprime_strides
from repro.core.types import FLConfig
from repro.data.pipeline import cohort_data_fn
from repro.data.synthetic import FedDataConfig, sample_round


def fuzz(*strategies, fallback, max_examples=10):
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=max_examples, deadline=None)(
                given(*strategies)(fn))
        nargs = fn.__code__.co_argcount
        argnames = ",".join(fn.__code__.co_varnames[:nargs])
        vals = [t[0] for t in fallback] if nargs == 1 else fallback
        return pytest.mark.parametrize(argnames, vals)(fn)
    return deco


def _st(builder):
    return builder() if HAVE_HYPOTHESIS else None


CFG = get_arch("paper_lm")
PARAMS = {"w": jnp.zeros((40,), jnp.float32),
          "b": jnp.zeros((8,), jnp.float32)}


def _store(capacity=4, eviction="drop", **kw):
    pipe = uplink_pipeline(FLConfig(uplink_compressor="topk:0.25>>qsgd:8"))
    return ResidualStore(pipe, PARAMS, capacity, eviction=eviction, **kw)


def _rows(store, ids, val):
    """Constant-filled pipeline-state rows with an (M,) lead."""
    zero, _ = store.gather(store.init(), jnp.asarray(ids, jnp.int32))
    return jax.tree.map(lambda a: jnp.full_like(a, val), zero)


def _ids(*xs):
    return jnp.asarray(xs, jnp.int32)


def _row_leaves(rows):
    return jax.tree.leaves(rows)


# ---------------------------------------------------------------------------
# LRU slab
# ---------------------------------------------------------------------------

def test_slab_hit_roundtrip():
    store = _store(capacity=4)
    s = store.init()
    s = store.scatter(s, _ids(7, 3), _rows(store, [7, 3], 2.5))
    rows, _ = store.gather(s, _ids(3, 7))
    for leaf in _row_leaves(rows):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.full_like(np.asarray(leaf), 2.5))


def test_slab_miss_reads_zero_under_drop():
    store = _store(capacity=4)
    s = store.scatter(store.init(), _ids(7), _rows(store, [7], 1.0))
    rows, _ = store.gather(s, _ids(9))
    for leaf in _row_leaves(rows):
        assert not np.asarray(leaf).any()


def test_slab_lru_eviction_order():
    """Misses take free slots first, then the least-recently-committed
    occupant; hit slots are never reclaimed."""
    store = _store(capacity=4)
    s = store.init()
    s = store.scatter(s, _ids(0, 1), _rows(store, [0, 1], 1.0))   # clock 0
    s = store.scatter(s, _ids(2, 3), _rows(store, [2, 3], 2.0))   # clock 1
    # client 1 commits again => fresh stamp; 0 is now the LRU occupant
    s = store.scatter(s, _ids(1), _rows(store, [1], 3.0))         # clock 2
    s = store.scatter(s, _ids(9), _rows(store, [9], 4.0))         # evicts 0
    resident = set(np.asarray(s["client"]).tolist())
    assert resident == {1, 2, 3, 9}
    rows, _ = store.gather(s, _ids(0))
    for leaf in _row_leaves(rows):                 # 0's state dropped
        assert not np.asarray(leaf).any()
    rows, _ = store.gather(s, _ids(1))
    for leaf in _row_leaves(rows):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.full_like(np.asarray(leaf), 3.0))


def test_scatter_rejects_oversized_cohort():
    store = _store(capacity=2)
    with pytest.raises(ValueError, match="exceeds store capacity"):
        store.scatter(store.init(), _ids(0, 1, 2), _rows(store, [0, 1, 2], 1.0))


def test_store_memory_flat_in_population():
    """The scale claim at unit level: the store footprint depends on
    capacity, never on how many clients exist or which ids pass through."""
    small = ClientPopulation(n_clients=10_000, cohort=16, capacity=64)
    large = ClientPopulation(n_clients=1_000_000, cohort=16, capacity=64)
    pipe = uplink_pipeline(FLConfig(uplink_compressor="topk:0.25>>qsgd:8"))
    b_small = store_nbytes(small.make_store(pipe, PARAMS).init())
    b_large = store_nbytes(large.make_store(pipe, PARAMS).init())
    assert b_small == b_large > 0


# ---------------------------------------------------------------------------
# count-sketch tail
# ---------------------------------------------------------------------------

def _sparse_rows(store, ids, coord, val):
    zero, _ = store.gather(store.init(), jnp.asarray(ids, jnp.int32))
    return jax.tree.map(
        lambda a: (a.at[:, coord].set(val)
                   if a.ndim == 2 and a.shape[1] > coord else a), zero)


def test_sketch_tail_recovers_evicted_heavy_mass():
    """A sparse heavy row survives eviction: fold into the tail, then a
    later gather of the evicted id recovers most of the mass (count-sketch
    heavy-hitter recovery), and the recovered mass leaves the tail."""
    store = _store(capacity=1, eviction="sketch", tail_rows=5,
                   tail_cols=1024)
    s = store.init()
    s = store.scatter(s, _ids(0), _sparse_rows(store, [0], 3, 5.0))
    s = store.scatter(s, _ids(1), _rows(store, [1], 0.0))   # evicts + folds 0
    tail_before = sum(float((t ** 2).sum()) for t in jax.tree.leaves(s["tail"]))
    assert tail_before > 0.0
    rows, s2 = store.gather(s, _ids(0))
    got = [np.asarray(l) for l in _row_leaves(rows) if np.asarray(l).ndim == 2]
    heavy = max(abs(float(l[0, 3])) for l in got if l.shape[1] > 3)
    assert heavy > 2.5, f"recovered {heavy}, expected most of 5.0"
    tail_after = sum(float((t ** 2).sum()) for t in jax.tree.leaves(s2["tail"]))
    assert tail_after < tail_before


@fuzz(_st(lambda: st.integers(0, 2 ** 16)),
      fallback=[(0,), (7,), (1234,), (99999,)])
def test_sketch_recovery_never_amplifies(seed):
    """Energy conservation: a gather can only shrink the tail, whatever is
    in it — the property that keeps recover -> EF -> re-fold contractive
    (naive subtract-on-recover fails this and diverges in training)."""
    store = _store(capacity=2, eviction="sketch", tail_rows=5,
                   tail_cols=256)
    key = jax.random.PRNGKey(seed)
    s = store.init()
    tail = jax.tree.map(
        lambda t: jax.random.normal(jax.random.fold_in(key, t.size),
                                    t.shape) if t.size else t, s["tail"])
    s = dict(s, tail=tail)
    before = sum(float((t ** 2).sum()) for t in jax.tree.leaves(s["tail"]))
    _, s2 = store.gather(s, _ids(5, 11))
    after = sum(float((t ** 2).sum()) for t in jax.tree.leaves(s2["tail"]))
    assert after <= before * (1 + 1e-5)


def test_sketch_fold_is_masked_linear():
    """Zero rows fold to nothing: scattering only hits (no evictions)
    leaves the tail untouched."""
    store = _store(capacity=4, eviction="sketch", tail_cols=256)
    s = store.init()
    s = store.scatter(s, _ids(0, 1), _rows(store, [0, 1], 1.5))
    s = store.scatter(s, _ids(0, 1), _rows(store, [0, 1], 2.5))  # all hits
    assert all(not np.asarray(t).any() for t in jax.tree.leaves(s["tail"]))


def test_checkpointable_state_is_plain_pytree():
    store = _store(capacity=3, eviction="sketch")
    leaves = jax.tree.leaves(store.init())
    assert leaves and all(hasattr(l, "dtype") for l in leaves)


# ---------------------------------------------------------------------------
# samplers
# ---------------------------------------------------------------------------

def test_coprime_strides_are_coprime_and_bounded():
    import math
    for C, M in [(100_000, 1024), (65537, 16), (12, 5), (2, 1)]:
        strides = _coprime_strides(C, M)
        assert strides.size > 0
        for s in strides.tolist():
            assert 1 <= s <= (2 ** 31 - 1) // max(M, 1)
            assert math.gcd(int(s), C) == 1


@fuzz(_st(lambda: st.integers(0, 1000)),
      fallback=[(0,), (1,), (17,), (555,)])
def test_stride_cohorts_are_unique_and_in_range(r):
    pop = ClientPopulation(n_clients=100_003, cohort=256, sampler="stride")
    ids = np.asarray(pop.cohort_ids(r))
    assert ids.dtype == np.int32
    assert len(set(ids.tolist())) == 256
    assert ids.min() >= 0 and ids.max() < 100_003


def test_shuffle_cohorts_are_unique_and_vary():
    pop = ClientPopulation(n_clients=1000, cohort=64, sampler="shuffle")
    a = np.asarray(pop.cohort_ids(0))
    b = np.asarray(pop.cohort_ids(1))
    assert len(set(a.tolist())) == 64
    assert not np.array_equal(a, b)


def test_degenerate_cohort_is_identity():
    pop = ClientPopulation(n_clients=8)
    np.testing.assert_array_equal(np.asarray(pop.cohort_ids(3)),
                                  np.arange(8, dtype=np.int32))
    assert pop.capacity == 8


def test_availability_mask_extremes_and_rate():
    pop = ClientPopulation(n_clients=10_000, cohort=512, availability=0.5)
    m = np.asarray(pop.availability_mask(0, pop.cohort_ids(0)))
    assert set(np.unique(m).tolist()) <= {0.0, 1.0}
    assert 0.3 < m.mean() < 0.7
    full = ClientPopulation(n_clients=100, cohort=16)
    assert np.asarray(full.availability_mask(0, full.cohort_ids(0))).all()


def test_population_validation():
    with pytest.raises(ValueError, match="cohort"):
        ClientPopulation(n_clients=4, cohort=9)
    with pytest.raises(ValueError, match="capacity"):
        ClientPopulation(n_clients=100, cohort=10, capacity=5)
    with pytest.raises(ValueError, match="shuffle"):
        ClientPopulation(n_clients=10_000_000, cohort=8, sampler="shuffle")
    with pytest.raises(ValueError, match="eviction"):
        ClientPopulation(n_clients=8, eviction="lossless")
    with pytest.raises(ValueError, match="availability"):
        ClientPopulation(n_clients=8, availability=0.0)


# ---------------------------------------------------------------------------
# degenerate bit-exactness vs the dense engines
# ---------------------------------------------------------------------------

DATA = FedDataConfig(vocab_size=CFG.vocab_size, num_clients=4, seq_len=32,
                     batch_per_client=2, heterogeneity=1.5)


def _data_fn(r):
    return sample_round(DATA, jax.random.fold_in(jax.random.PRNGKey(1), r))


def _run_engine(model, fl, topo, pop, n, seed=0):
    e = make_round_engine(model, fl, topo, chunk=32, data_fn=_data_fn,
                          population=pop)
    st = e.init_fn(jax.random.PRNGKey(seed))
    st, _ = run_rounds(e, st, _data_fn, n, chunk=2, donate=False)
    comm = (st.comm_state["slab"] if isinstance(st.comm_state, dict)
            else st.comm_state)
    return st.params, comm


def _assert_bitexact(model, fl, topo, n, seed=0):
    dense = _run_engine(model, fl, topo, None, n, seed)
    pop = ClientPopulation(n_clients=4, cohort=4, capacity=4)
    stream = _run_engine(model, fl, topo, pop, n, seed)
    for what, a, b in [("params", dense[0], stream[0]),
                      ("comm_state", dense[1], stream[1])]:
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert np.array_equal(np.asarray(la), np.asarray(lb)), (
                f"{what} diverged: {fl.uplink_compressor} on {topo.kind}")


@pytest.mark.parametrize("spec", [
    "topk:0.25>>qsgd:8",            # stateful EF chain
    "topk:0.25@kernel>>qsgd:8",     # same chain through the Pallas path
    "qsgd8",                        # stateless (store is None)
])
def test_degenerate_bitexact_sim(spec):
    from repro.models.model import Model
    model = Model(CFG)
    fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                  uplink_compressor=spec)
    _assert_bitexact(model, fl, Topology.sim(4), n=3)


@fuzz(_st(lambda: st.integers(0, 2 ** 16)), fallback=[(0,), (42,)],
      max_examples=3)
def test_degenerate_bitexact_sim_any_seed(seed):
    from repro.models.model import Model
    model = Model(CFG)
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="topk:0.25>>qsgd:8")
    _assert_bitexact(model, fl, Topology.sim(4), n=2, seed=seed)


def test_degenerate_bitexact_async():
    from repro.models.model import Model
    model = Model(CFG)
    fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                  uplink_compressor="topk:0.25>>qsgd:8",
                  latency_profile="constant")
    topo = Topology.async_(4, buffer_size=4, latency_profile="constant")
    _assert_bitexact(model, fl, topo, n=8)


# ---------------------------------------------------------------------------
# partial cohorts actually train
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eviction", ["drop", "sketch"])
def test_partial_cohort_sim_trains(eviction):
    from repro.models.model import Model
    model = Model(CFG)
    pop = ClientPopulation(n_clients=32, cohort=8, capacity=12,
                           eviction=eviction, tail_cols=512)
    dcfg = FedDataConfig(vocab_size=CFG.vocab_size, num_clients=32,
                         seq_len=32, batch_per_client=2, heterogeneity=1.5)
    dfn = cohort_data_fn(pop, dcfg)
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="topk:0.25>>qsgd:8")
    e = make_round_engine(model, fl, Topology.sim(32), chunk=32,
                          population=pop)
    st = e.init_fn(jax.random.PRNGKey(0))
    b0 = store_nbytes(st.comm_state)
    st, ms = run_rounds(e, st, dfn, 3, chunk=1, donate=False)
    assert np.isfinite(np.asarray(ms["loss"])).all()
    assert store_nbytes(st.comm_state) == b0
    resident = np.asarray(st.comm_state["client"])
    assert resident.max() < 32 and (resident >= -1).all()


def test_partial_cohort_async_trains():
    from repro.models.model import Model
    model = Model(CFG)
    pop = ClientPopulation(n_clients=64, cohort=8, capacity=16)
    dcfg = FedDataConfig(vocab_size=CFG.vocab_size, num_clients=64,
                         seq_len=32, batch_per_client=2, heterogeneity=1.5)
    dfn = cohort_data_fn(pop, dcfg)
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="topk:0.25>>qsgd:8",
                  latency_profile="heavy_tail")
    e = make_round_engine(model, fl, Topology.async_(64, buffer_size=2),
                          chunk=32, data_fn=dfn, population=pop)
    st = e.init_fn(jax.random.PRNGKey(0))
    st, ms = run_rounds(e, st, dfn, 12, chunk=4, donate=False)
    assert np.isfinite(np.asarray(ms["loss"])).all()
    assert np.asarray(st.comm_state["client"]).max() < 64


def test_availability_churn_runs():
    from repro.models.model import Model
    model = Model(CFG)
    pop = ClientPopulation(n_clients=32, cohort=8, availability=0.75)
    dcfg = FedDataConfig(vocab_size=CFG.vocab_size, num_clients=32,
                         seq_len=32, batch_per_client=2, heterogeneity=1.5)
    dfn = cohort_data_fn(pop, dcfg)
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="qsgd8")
    e = make_round_engine(model, fl, Topology.sim(32), chunk=32,
                          population=pop)
    st = e.init_fn(jax.random.PRNGKey(0))
    st, ms = run_rounds(e, st, dfn, 2, chunk=1, donate=False)
    assert np.isfinite(np.asarray(ms["loss"])).all()


# ---------------------------------------------------------------------------
# dense-build guard rails
# ---------------------------------------------------------------------------

def test_dense_stateful_above_limit_names_the_population_api():
    from repro.models.model import Model
    model = Model(CFG)
    fl = FLConfig(uplink_compressor="topk:0.25>>qsgd:8")
    with pytest.raises(ValueError) as ei:
        make_round_engine(model, fl,
                          Topology.sim(POPULATION_DENSE_LIMIT + 1), chunk=32)
    msg = str(ei.value)
    assert "ClientPopulation" in msg and "--population" in msg


def test_dense_stateless_above_limit_is_legal():
    from repro.models.model import Model
    model = Model(CFG)
    fl = FLConfig(uplink_compressor="qsgd8")
    make_round_engine(model, fl, Topology.sim(POPULATION_DENSE_LIMIT + 1),
                      chunk=32)     # builds: no per-client rows to allocate


def test_population_rejects_gossip_and_scaffold():
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import Model
    model = Model(CFG)
    pop = ClientPopulation(n_clients=4, cohort=4)
    with pytest.raises(ValueError, match="star/sim/async"):
        make_round_engine(model, FLConfig(), Topology.gossip(),
                          mesh=make_host_mesh(model=1), population=pop)
    with pytest.raises(ValueError, match="scaffold"):
        make_round_engine(model, FLConfig(algorithm="scaffold"),
                          Topology.sim(4), chunk=32, population=pop)
