"""Differential parity harness: kernel backend vs pure JAX (DESIGN.md §6).

Every kernel-capable stage, every combined-sweep chain, and the EF/DGC
wrappers run through BOTH backends on identical inputs (tests/parity_cases
table). Assertions per case:

  * decoded payloads match — bit-exact for the deterministic layouts,
    bounded-tolerance where padding/blocking reorders a reduction;
  * comm_state (EF residual / DGC momentum / warm-up counter) evolves
    identically across rounds;
  * ledger byte counts (`wire_bits` / `entropy_bits`) are identical —
    kernel-layout padding never reaches the ledger.

Runs in Pallas interpret mode on CPU CI; the same table validates on real
TPU unchanged (`repro.kernels.ops._interpret` switches on the backend).

Also here: the `_to_blocked` padding property tests (hypothesis-optional
with fixed-seed fallbacks, per tests/test_compressors.py convention) and
the engine-level `FLConfig.backend` threading checks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import make_compressor
from repro.kernels import ops

# the hypothesis-optional fuzz helper is shared with the compressor suite
from test_compressors import HAVE_HYPOTHESIS, _st, fuzz
from parity_cases import ALL_CASES, INPUTS, build

if HAVE_HYPOTHESIS:
    from hypothesis import strategies as st

IDS = [c["name"] for c in ALL_CASES]


def _assert_close(a, b, exact, tol, what):
    a, b = np.asarray(a), np.asarray(b)
    if exact or a.dtype.kind in "iub":
        np.testing.assert_array_equal(a, b, err_msg=what)
    else:
        scale = max(float(np.abs(a).max()), 1e-6)
        np.testing.assert_allclose(a, b, rtol=tol, atol=tol * scale,
                                   err_msg=what)


# ---------------------------------------------------------------------------
# The differential harness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c", ALL_CASES, ids=IDS)
def test_backend_parity(c):
    input_fn = INPUTS[c["input"]]
    pure = build(c, "jax")
    kern = build(c, "kernel")
    for n in c["sizes"]:
        # --- ledger: identical byte counts, pad lanes never billed --------
        assert kern.wire_bits(n) == pure.wire_bits(n), (c["name"], n)
        assert kern.entropy_bits(n) == pure.entropy_bits(n), (c["name"], n)

        st_p, st_k = pure.init((n,)), kern.init((n,))
        for r in range(c["rounds"]):
            x = input_fn(1000 * r + n, n)
            rng = jax.random.fold_in(jax.random.PRNGKey(7), r)
            pay_p, st_p = pure.encode(st_p, rng, x)
            pay_k, st_k = kern.encode(st_k, rng, x)
            # layout contract: kernel payload SHAPES equal the pure path's
            # (what crosses the collectives — grid padding never ships)
            assert jax.tree.map(jnp.shape, pay_k) == \
                jax.tree.map(jnp.shape, pay_p), (c["name"], n, r)
            y_p = pure.decode(pay_p, n)
            y_k = kern.decode(pay_k, n)
            _assert_close(y_p, y_k, c["exact"], c["tol"],
                          f"{c['name']} n={n} round={r}: decoded payload")
            # support parity holds even for the tolerance classes: a
            # reduction reorder may move mu by ULPs, never the mask
            np.testing.assert_array_equal(
                np.asarray(y_p) == 0, np.asarray(y_k) == 0,
                err_msg=f"{c['name']} n={n} round={r}: support")
            for lp, lk in zip(jax.tree.leaves(st_p), jax.tree.leaves(st_k)):
                _assert_close(lp, lk, c["exact"], c["tol"],
                              f"{c['name']} n={n} round={r}: comm_state")


def test_kernel_names_tagged():
    """`@kernel` stages are visible in the pipeline name (debuggability)."""
    assert make_compressor("qsgd:8", backend="kernel").name == "qsgd8@kernel"
    assert make_compressor("topk:0.01@kernel>>qsgd:8").name == \
        "topk0.01@kernel>>qsgd8"


def test_explicit_kernel_on_uncapable_stage_fails():
    for spec in ("hsq@kernel", "sbc:0.01@kernel", "randmask:0.05@kernel",
                 "uveq:4@kernel"):
        with pytest.raises(ValueError, match="no kernel backend"):
            make_compressor(spec)
    # ...but the global backend kwarg degrades gracefully to pure JAX
    assert make_compressor("hsq", backend="kernel").name == "hsq"


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        make_compressor("qsgd:8@gpu")
    with pytest.raises(ValueError, match="unknown backend"):
        make_compressor("qsgd:8", backend="tpu")


# ---------------------------------------------------------------------------
# _to_blocked padding properties (satellite: arbitrary n vs block/ROWS)
# ---------------------------------------------------------------------------

@fuzz(_st(lambda: st.integers(1, 40_000)),
      _st(lambda: st.sampled_from([128, 256, 512, 2048])),
      fallback=[(1, 128), (100, 256), (2048, 2048), (2049, 128),
                (4096, 512), (5000, 2048), (8 * 2048, 2048),
                (8 * 2048 + 1, 2048)])
def test_to_blocked_padding_roundtrip(n, block):
    x = jax.random.normal(jax.random.PRNGKey(n % 997), (n,))
    xb, pad = ops._to_blocked(x, block)
    assert xb.shape[0] % ops.ROWS == 0
    assert xb.shape[1] == block
    assert pad == xb.size - n
    flat = np.asarray(xb.reshape(-1))
    np.testing.assert_array_equal(flat[:n], np.asarray(x, np.float32))
    assert not flat[n:].any(), "pad lanes must be zero"


@fuzz(_st(lambda: st.integers(1, 40_000)),
      fallback=[(1,), (100,), (2048,), (3001,), (5000,), (8 * 2048,)])
def test_pad_lanes_never_billed(n):
    """Kernel payloads are sliced to the logical ceil(n/block) rows, and the
    ledger formulas are identical to the pure twin for arbitrary n — no
    payload bytes are ever attributed to grid-pad lanes."""
    block = 2048
    kern = make_compressor("qsgd:8", backend="kernel")
    pure = make_compressor("qsgd:8")
    x = jax.random.normal(jax.random.PRNGKey(n % 991), (n,))
    pay, _ = kern.encode((), jax.random.PRNGKey(0), x)
    nb_logical = -(-n // block)
    assert pay["q"].shape[0] == nb_logical
    assert pay["scale"].shape == (nb_logical,)
    assert kern.meta_bits(n) == pure.meta_bits(n) == 8.0 * n + 32.0 * nb_logical
    assert kern.wire_bits(n) == pure.wire_bits(n)


def test_stc_ternarize_accepts_traced_fraction():
    """The fused STC op must be static-shape-safe for a *traced* fraction —
    the DGC warm-up anneals it per round (MomentumCorrection._anneal_mask)."""
    n = 5000
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))

    @jax.jit
    def annealed(frac):
        return ops.stc_ternarize(x, frac, block=2048)

    code, mu = annealed(jnp.float32(0.05))
    assert code.shape == (n,)
    k = int(round(n * 0.05))
    assert int((code != 0).sum()) >= k
    # matches the static-fraction call (signs exactly; mu to float tolerance
    # — jit-vs-eager may fuse the tiny mu reduction differently)
    code2, mu2 = ops.stc_ternarize(x, 0.05, block=2048)
    np.testing.assert_array_equal(np.asarray(code), np.asarray(code2))
    np.testing.assert_allclose(float(mu), float(mu2), rtol=1e-6)
    # annealing down transmits fewer coordinates
    code3, _ = annealed(jnp.float32(0.01))
    assert int((code3 != 0).sum()) < int((code != 0).sum())
    # max_fraction bounds the top_k prefix (the DGC schedule's static
    # round-0 fraction) without changing the result: bit-identical codes
    # for every traced fraction at or below the bound
    @jax.jit
    def bounded(frac):
        return ops.stc_ternarize(x, frac, block=2048, max_fraction=0.05)

    for f in (0.05, 0.03, 0.01):
        cb, mb = bounded(jnp.float32(f))
        cu, mu_u = annealed(jnp.float32(f))
        np.testing.assert_array_equal(np.asarray(cb), np.asarray(cu))
        np.testing.assert_allclose(float(mb), float(mu_u), rtol=1e-6)


# ---------------------------------------------------------------------------
# Engine-level backend threading (sim path; the hier edge hop is covered by
# distributed_cases.case_kernel_backend_edge_hop)
# ---------------------------------------------------------------------------

def _sim_run(backend, rounds=2):
    from repro.configs.registry import get_arch
    from repro.core.engine import run_rounds
    from repro.core.simulate import make_sim_step
    from repro.core.types import FLConfig
    from repro.data.synthetic import FedDataConfig, sample_round
    from repro.models.model import Model

    cfg = get_arch("paper_lm")
    model = Model(cfg)
    data = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=4,
                         seq_len=32, batch_per_client=2, heterogeneity=1.5)
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="topk:0.05>>qsgd:8", backend=backend)
    sim = make_sim_step(model, fl, data.num_clients, chunk=32)
    state = sim.init_fn(jax.random.PRNGKey(0))
    state, ms = run_rounds(
        sim.engine, state,
        lambda r: sample_round(data, jax.random.fold_in(
            jax.random.PRNGKey(1), r)), rounds, chunk=rounds)
    return state, ms


def test_engine_backend_threading():
    """FLConfig.backend='kernel' through the sim engine: params and EF
    comm_state match pure JAX within the engine-scope ULP band (the
    pallas_call boundary changes XLA's FMA fusion of surrounding f32 math
    — DESIGN.md §6; supports still match exactly), and the per-round
    ledger bytes bit-match."""
    s_jax, m_jax = _sim_run("jax")
    s_ker, m_ker = _sim_run("kernel")
    for a, b in zip(jax.tree.leaves(s_jax.params),
                    jax.tree.leaves(s_ker.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-8)
    for a, b in zip(jax.tree.leaves(s_jax.comm_state),
                    jax.tree.leaves(s_ker.comm_state)):
        np.testing.assert_array_equal(np.asarray(a) == 0, np.asarray(b) == 0)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-8)
    np.testing.assert_array_equal(np.asarray(m_jax["ledger"].uplink_wire),
                                  np.asarray(m_ker["ledger"].uplink_wire))


def test_ledger_terms_identical_across_backends():
    from repro.configs.registry import get_arch
    from repro.core.engine import ledger_terms
    from repro.core.types import FLConfig
    from repro.models.model import Model
    model = Model(get_arch("paper_lm"))
    for spec in ("stc", "topk:0.01>>qsgd:8", "sketch>>qsgd:8"):
        t_jax, _, _ = ledger_terms(model, FLConfig(uplink_compressor=spec,
                                                   backend="jax"))
        t_ker, _, _ = ledger_terms(model, FLConfig(uplink_compressor=spec,
                                                   backend="kernel"))
        assert t_jax == t_ker, spec


# ---------------------------------------------------------------------------
# Packed wire formats (DESIGN.md §10): pack/unpack round-trips, the
# ledger == payload-bytes invariant, fused-vs-staged equivalence, grammar
# ---------------------------------------------------------------------------

from repro.compress.wire_format import (pack2, pack4, payload_nbytes,
                                        unpack2, unpack4)
from repro.kernels import bitpack

# every spec that can ship packed (the "@fused" surface); qsgd bits > 4 and
# the index/sketch/sign stages have no packed form and must stay staged
PACKABLE_SPECS = ("ternary@fused", "qsgd:4@fused", "qsgd:2@fused",
                  "stc:0.1@fused", "topk:0.05>>qsgd:4@fused",
                  "topk:0.1>>ternary@fused", "stc@fused")


@fuzz(_st(lambda: st.integers(1, 20_000)),
      _st(lambda: st.sampled_from([2, 4])),
      fallback=[(1, 2), (3, 2), (4, 2), (100, 4), (3001, 2), (5000, 4),
                (8 * 2048, 2), (8 * 2048, 4)])
def test_pack_unpack_roundtrip_bitexact(n, bits):
    """pack2/pack4 are lossless on their code range and the tail byte's
    unused fields are zero (pad codes never leak onto the wire)."""
    lo, hi = (-1, 1) if bits == 2 else (-8, 7)
    codes = jax.random.randint(jax.random.PRNGKey(n * 8 + bits), (n,),
                               lo, hi + 1, dtype=jnp.int8)
    pack, unpack, per = (pack2, unpack2, 4) if bits == 2 else \
        (pack4, unpack4, 2)
    packed = pack(codes)
    assert packed.dtype == jnp.uint8 and packed.shape == (-(-n // per),)
    np.testing.assert_array_equal(np.asarray(unpack(packed, n)),
                                  np.asarray(codes))
    if n % per:  # tail fields beyond n must pack to zero bits
        tail = int(np.asarray(packed)[-1]) >> (bits * (n % per))
        assert tail == 0


@fuzz(_st(lambda: st.integers(1, 20_000)),
      _st(lambda: st.sampled_from([2, 4])),
      fallback=[(1, 2), (100, 4), (2048, 2), (3001, 4), (5000, 2),
                (8 * 2048, 4)])
def test_pallas_pack_kernels_match_flat_packing(n, bits):
    """The Pallas pack/unpack kernels, flattened and sliced to the logical
    length, emit BIT-identical bytes to the pure flat packing — the property
    that makes the fused payloads interchangeable across backends."""
    lo, hi = (-1, 1) if bits == 2 else (-8, 7)
    codes = jax.random.randint(jax.random.PRNGKey(n * 4 + bits), (n,),
                               lo, hi + 1, dtype=jnp.int8)
    block = 2048
    cb, _ = ops._to_blocked(codes.astype(jnp.float32), block)
    cb = cb.astype(jnp.int8)
    per = 8 // bits
    packed_k = bitpack.pack_codes_blocked(cb, bits, interpret=True)
    flat_k = packed_k.reshape(-1)[:-(-n // per)]
    flat_p = (pack2 if bits == 2 else pack4)(codes)
    np.testing.assert_array_equal(np.asarray(flat_k), np.asarray(flat_p))
    # kernel unpack inverts kernel pack on the blocked layout
    back = bitpack.unpack_codes_blocked(packed_k, bits, interpret=True)
    np.testing.assert_array_equal(np.asarray(back.reshape(-1)[:n]),
                                  np.asarray(codes))


@fuzz(_st(lambda: st.sampled_from(PACKABLE_SPECS)),
      _st(lambda: st.integers(8, 40_000)),
      fallback=[(s, n) for s in PACKABLE_SPECS for n in (100, 5000)])
def test_packed_payload_bytes_equal_ledger(spec, n):
    """THE tentpole invariant: for every packable spec the bytes the
    aggregation collective actually gathers (payload_nbytes via eval_shape)
    equal the ledger's wire_bits/8 exactly, on both backends — and packing
    strictly shrinks the wire vs the staged twin."""
    staged = make_compressor(spec.replace("@fused", ""))
    for backend in ("jax", "kernel"):
        pipe = make_compressor(spec, backend=backend)
        assert 8 * payload_nbytes(pipe, n) == pipe.wire_bits(n), \
            (spec, backend, n)
        # packing strictly shrinks the wire vs the staged twin — except
        # stc@fused at the default fraction 0.01, where the dense 2-bit
        # sign plane (2n bits) loses to the staged index list (~40*k bits);
        # the dense plane wins exactly when fraction > 2/40 (DESIGN.md §10)
        if spec != "stc@fused":
            assert pipe.wire_bits(n) < staged.wire_bits(n), \
                (spec, backend, n)


def test_fused_stc_matches_staged_pipeline():
    """stc@fused (single threshold-ternarize pass) reconstructs the same
    update as the staged topk>>ternary pipeline: identical support, values
    within mu's reduction-order tolerance, strictly fewer wire bits."""
    n = 5000
    x = jax.random.normal(jax.random.PRNGKey(3), (n,)) * 2.0
    staged = make_compressor("stc:0.1")
    fused = make_compressor("stc:0.1@fused")
    pay_s, _ = staged.encode(staged.init((n,)), jax.random.PRNGKey(0), x)
    pay_f, _ = fused.encode(fused.init((n,)), jax.random.PRNGKey(0), x)
    y_s = np.asarray(staged.decode(pay_s, n))
    y_f = np.asarray(fused.decode(pay_f, n))
    np.testing.assert_array_equal(y_s == 0, y_f == 0)
    np.testing.assert_allclose(y_s, y_f, rtol=1e-5, atol=1e-5)
    assert fused.wire_bits(n) < staged.wire_bits(n)


def test_fused_names_tagged():
    assert make_compressor("ternary@fused").name == "ternary@fused"
    assert make_compressor("stc:0.1@fused").name == "stc0.1@fused"
    assert make_compressor("qsgd:4@fused@kernel").name == "qsgd4@kernel@fused"
    assert make_compressor("topk:0.05>>qsgd:4@fused").name == \
        "topk0.05>>qsgd4@fused"


def test_explicit_fused_on_unpackable_stage_fails():
    for spec in ("topk:0.05@fused", "qsgd:8@fused", "sbc:0.01@fused",
                 "hsq@fused", "sketch@fused"):
        with pytest.raises(ValueError, match="no packed wire format"):
            make_compressor(spec)
    with pytest.raises(ValueError, match="unknown wire format"):
        make_compressor("ternary", wire_format="zipped")


def test_global_wire_format_degrades_gracefully():
    """FLConfig.wire_format='packed' packs every packable stage and leaves
    the rest staged (same graceful-degrade contract as backend='kernel')."""
    assert make_compressor("qsgd:8", wire_format="packed").name == "qsgd8"
    assert make_compressor("qsgd:4", wire_format="packed").name == \
        "qsgd4@fused"
    assert make_compressor("stc", wire_format="packed").name == \
        "stc0.01@fused"
    assert make_compressor("hsq", wire_format="packed").name == "hsq"
    # staged remains the default everywhere
    assert make_compressor("stc").name == "topk0.01>>ternary"


def test_engine_wire_format_packed_halves_uplink():
    """FLConfig.wire_format='packed' through the sim engine: the decoded
    aggregate matches staged within mu tolerance and the ledger's wire
    bytes drop by ~2x (int8 signs -> 2-bit packed, per-leaf +32-bit mu)."""
    from repro.configs.registry import get_arch
    from repro.core.engine import run_rounds
    from repro.core.simulate import make_sim_step
    from repro.core.types import FLConfig
    from repro.data.synthetic import FedDataConfig, sample_round
    from repro.models.model import Model

    cfg = get_arch("paper_lm")
    model = Model(cfg)
    data = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=4,
                         seq_len=32, batch_per_client=2, heterogeneity=1.5)

    def run(wire):
        fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                      uplink_compressor="stc:0.1", wire_format=wire)
        sim = make_sim_step(model, fl, data.num_clients, chunk=32)
        state = sim.init_fn(jax.random.PRNGKey(0))
        return run_rounds(
            sim.engine, state,
            lambda r: sample_round(data, jax.random.fold_in(
                jax.random.PRNGKey(1), r)), 2, chunk=2)

    s_stg, m_stg = run("staged")
    s_pkd, m_pkd = run("packed")
    for a, b in zip(jax.tree.leaves(s_stg.params),
                    jax.tree.leaves(s_pkd.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    wire_stg = float(np.asarray(m_stg["ledger"].uplink_wire)[-1])
    wire_pkd = float(np.asarray(m_pkd["ledger"].uplink_wire)[-1])
    assert wire_pkd < 0.6 * wire_stg, (wire_pkd, wire_stg)


def test_async_engine_moves_packed_payloads():
    """The async dispatch path ships the packed buffers unchanged: a FedBuff
    run with wire_format='packed' stays finite and its per-event ledger
    reports the packed byte counts."""
    from repro.configs.registry import get_arch
    from repro.core.engine import Topology, make_round_engine, run_rounds
    from repro.core.types import FLConfig
    from repro.data.synthetic import FedDataConfig, sample_round
    from repro.models.model import Model

    cfg = get_arch("paper_lm")
    model = Model(cfg)
    data = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=4,
                         seq_len=32, batch_per_client=2, heterogeneity=1.5)

    def data_fn(r):
        return sample_round(data, jax.random.fold_in(jax.random.PRNGKey(1), r))

    def run(wire):
        fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                      uplink_compressor="stc:0.1", wire_format=wire)
        eng = make_round_engine(model, fl, Topology.async_(4, buffer_size=2),
                                chunk=32, data_fn=data_fn)
        return run_rounds(eng, eng.init_fn(jax.random.PRNGKey(0)),
                          data_fn, 8, chunk=4)

    _, m_stg = run("staged")
    _, m_pkd = run("packed")
    assert np.isfinite(np.asarray(m_pkd["loss"])).all()
    wire_stg = float(np.asarray(m_stg["ledger"].uplink_wire)[-1])
    wire_pkd = float(np.asarray(m_pkd["ledger"].uplink_wire)[-1])
    assert 0 < wire_pkd < 0.6 * wire_stg, (wire_pkd, wire_stg)
