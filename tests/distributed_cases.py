"""Multi-device integration cases, run in a subprocess with 8 host devices
(tests/test_distributed.py drives this; the device count must be set before
jax import, which pytest's own process must not do — see dry-run notes)."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                                      # noqa: E402
import jax.numpy as jnp                                         # noqa: E402
import numpy as np                                              # noqa: E402

from repro.core.compat import make_mesh                        # noqa: E402
from repro.core.types import ArchConfig, FLConfig               # noqa: E402
from repro.core.federated import make_fl_train_step             # noqa: E402
from repro.core.hierarchical import make_hier_fl_train_step     # noqa: E402
from repro.core.gossip import make_gossip_step                  # noqa: E402
from repro.models.model import Model                            # noqa: E402
from repro.data.synthetic import FedDataConfig, sample_round    # noqa: E402


def tiny_cfg(**kw):
    d = dict(name="tiny", family="dense", num_layers=2, d_model=64,
             num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
             block_pattern=("attn+mlp",), dtype=jnp.float32, remat=False)
    d.update(kw)
    return ArchConfig(**d)


def mesh3():
    return make_mesh((2, 2, 2), ("pod", "data", "model"))


def mesh2():
    return make_mesh((4, 2), ("data", "model"))


def make_batch(cfg, C, B, S, key):
    t = jax.random.randint(key, (C, B, S), 0, cfg.vocab_size)
    return {"tokens": t, "labels": t, "mask": jnp.ones((C, B, S)),
            "sizes": jnp.ones((C,)),
            "resources": jax.random.uniform(key, (C, 4))}


# ---------------------------------------------------------------------------

def case_fedsgd_equals_centralized():
    """FedSGD + identity compression + all clients == one centralized SGD
    step over the union batch (exactness of the aggregation wire)."""
    cfg = tiny_cfg()
    model = Model(cfg)
    mesh = mesh2()
    fl = FLConfig(algorithm="fedsgd", local_steps=1, local_lr=0.1,
                  uplink_compressor="none", server_opt="fedavg", server_lr=1.0)
    step = make_fl_train_step(model, fl, mesh, chunk=16)
    state = step.init_fn(jax.random.PRNGKey(0))
    C, B, S = step.n_clients, 2, 16
    batch = make_batch(cfg, C, B, S, jax.random.PRNGKey(1))
    new_state, _ = jax.jit(step.step_fn)(state, batch)

    # centralized: same init, SGD over the concatenated batch
    params = model.init(jax.random.PRNGKey(0))
    flat = {k: v.reshape((-1,) + v.shape[2:]) for k, v in batch.items()
            if k in ("tokens", "labels", "mask")}
    g = jax.grad(lambda p: model.loss(p, flat, chunk=16)[0])(params)
    ref = jax.tree.map(lambda p, g_: p - 0.1 * g_, params, g)

    err = max(float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree.leaves(new_state.params), jax.tree.leaves(ref)))
    assert err < 1e-5, err
    print("case_fedsgd_equals_centralized OK", err)


def case_all_algorithms_converge():
    cfg = tiny_cfg()
    model = Model(cfg)
    mesh = mesh3()
    for algo, comp, sel_, E, sopt, slr in [
        ("fedsgd", "none", "all", 1, "fedavg", 1.0),
        ("fedavg", "qsgd8", "all", 2, "fedavg", 1.0),
        ("fedavg", "qsgd4", "all", 1, "fedavg", 1.0),
        ("fedavg", "uveq", "all", 1, "fedavg", 1.0),
        ("fedavg", "topk", "random", 1, "fedadam", 0.05),
        ("fedavg", "stc", "power_of_choice", 2, "fedavg", 1.0),
        ("fedavg", "sbc", "all", 1, "fedavg", 1.0),
        ("scaffold", "none", "all", 2, "fedavg", 1.0),
        ("fedprox", "sketch", "all", 2, "fedavg", 1.0),
        ("fedavg", "hsq", "multi_criteria", 1, "fedavg", 1.0),
        ("fedavg", "randmask", "all", 1, "fedavg", 1.0),
        ("fedavg", "none", "all", 1, "fedyogi", 0.05),
        ("fedavg", "none", "all", 1, "fedavgm", 0.5),
    ]:
        fl = FLConfig(algorithm=algo, local_steps=E, uplink_compressor=comp,
                      downlink_compressor="lfl8" if comp == "qsgd8" else "none",
                      selection=sel_,
                      clients_per_round=3 if sel_ != "all" else 0,
                      fedprox_mu=0.01 if algo == "fedprox" else 0.0,
                      server_opt=sopt, server_lr=slr, sketch_cols=2048,
                      local_lr=0.02 if comp == "sketch" else 0.05,
                      topk_fraction=0.05)
        step = make_fl_train_step(model, fl, mesh, chunk=16)
        state = step.init_fn(jax.random.PRNGKey(0))
        batch = make_batch(cfg, step.n_clients, 2, 16, jax.random.PRNGKey(1))
        jstep = jax.jit(step.step_fn)
        losses = []
        for _ in range(3):
            state, m = jstep(state, batch)
            losses.append(float(m["loss_all"]))
        assert all(np.isfinite(losses)), (algo, comp, losses)
        assert losses[-1] < losses[0] + 0.05, (algo, comp, losses)
        led = m["ledger"]
        assert float(led.uplink_dense) > 0
        if comp not in ("none",):
            assert float(led.uplink_wire) < float(led.uplink_dense), comp
        print(f"  {algo}/{comp}/{sel_} OK {losses}")
    print("case_all_algorithms_converge OK")


def case_ledger_accounting_exact():
    cfg = tiny_cfg()
    model = Model(cfg)
    mesh = mesh2()
    fl = FLConfig(algorithm="fedsgd", uplink_compressor="none")
    step = make_fl_train_step(model, fl, mesh, chunk=16)
    state = step.init_fn(jax.random.PRNGKey(0))
    batch = make_batch(cfg, step.n_clients, 2, 16, jax.random.PRNGKey(1))
    _, m = jax.jit(step.step_fn)(state, batch)
    n_params = model.param_count()
    expect = 4.0 * n_params * step.n_clients       # f32 dense uplink
    got = float(m["ledger"].uplink_wire)
    assert abs(got - expect) / expect < 1e-6, (got, expect)
    print("case_ledger_accounting_exact OK", got)


def case_selection_counts():
    cfg = tiny_cfg()
    model = Model(cfg)
    mesh = mesh2()
    for sel_, m_exp in [("random", 2), ("power_of_choice", 2),
                        ("multi_criteria", 2), ("all", 4)]:
        fl = FLConfig(algorithm="fedsgd", selection=sel_, clients_per_round=2)
        step = make_fl_train_step(model, fl, mesh, chunk=16)
        state = step.init_fn(jax.random.PRNGKey(0))
        batch = make_batch(cfg, step.n_clients, 2, 16, jax.random.PRNGKey(1))
        _, m = jax.jit(step.step_fn)(state, batch)
        assert int(m["selected"]) == m_exp, (sel_, m["selected"])
    print("case_selection_counts OK")


def case_hier_and_gossip():
    cfg = tiny_cfg()
    model = Model(cfg)
    mesh = mesh3()
    fl = FLConfig(algorithm="fedavg", local_steps=2, uplink_compressor="qsgd8",
                  pod_compressor="qsgd8", hierarchical=True, sync_every=2)
    h = make_hier_fl_train_step(model, fl, mesh, chunk=16)
    state = h.init_fn(jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 2, 2, 16), 0, 96)
    batch = {"tokens": t, "labels": t, "mask": jnp.ones((2, 2, 2, 16))}
    se, sc = jax.jit(h.step_edge), jax.jit(h.step_cloud)
    divs, losses = [], []
    for i in range(4):
        stepf = sc if (i + 1) % 2 == 0 else se
        state, m = stepf(state, batch)
        divs.append(float(m["pod_divergence"]))
        losses.append(float(m["loss"]))
    assert divs[0] > 0 and divs[1] == 0.0 and divs[3] == 0.0, divs
    assert losses[-1] < losses[0], losses
    # edge-only round must report fewer wire bytes than cloud round
    assert h.terms["cloud_wire"] > 0

    flg = FLConfig(algorithm="fedavg", local_steps=1,
                   uplink_compressor="qsgd8", local_lr=0.01)
    g = make_gossip_step(model, flg, mesh, chunk=16)
    gs = g.init_fn(jax.random.PRNGKey(0))
    gs.params = jax.tree.map(lambda a: a + 0.1 * jax.random.normal(
        jax.random.PRNGKey(9), a.shape, a.dtype), gs.params)
    gstep = jax.jit(g.step_fn)
    gb = {"tokens": t[0], "labels": t[0], "mask": jnp.ones((2, 2, 16))}
    cons = []
    for _ in range(5):
        gs, m = gstep(gs, gb)
        cons.append(float(m["consensus"]))
    assert cons[-1] < cons[0] * 0.7, cons
    print("case_hier_and_gossip OK", divs, cons[:3])


def case_ef_residual_on_edge_hop():
    """RoundEngine EF fix: comm_state threads through the hierarchical edge
    hop and the gossip mix — under the biased chained pipeline
    "topk:0.01>>qsgd:8" the error-feedback residuals must be materialised in
    FLState.comm_state and EVOLVE across rounds on both topologies (they were
    silently stateless before the engine refactor)."""
    cfg = tiny_cfg()
    model = Model(cfg)
    mesh = mesh3()

    def res_norms(comm_state):
        return [float(jnp.abs(a).sum()) for st in comm_state
                for a in jax.tree.leaves(st)]

    # --- hierarchical edge hop --------------------------------------------
    fl = FLConfig(algorithm="fedavg", local_steps=2,
                  uplink_compressor="topk:0.01>>qsgd:8", topk_fraction=0.01,
                  pod_compressor="qsgd8", hierarchical=True, sync_every=2)
    h = make_hier_fl_train_step(model, fl, mesh, chunk=16)
    hs = h.init_fn(jax.random.PRNGKey(0))
    assert hs.comm_state is not None, "edge pipeline must own state"
    # per-client state grid: (G, Ce) leading dims on every leaf-shaped array
    lead = jax.tree.leaves(hs.comm_state[0])[0].shape[:2]
    assert lead == (2, 2), lead
    assert all(v == 0.0 for v in res_norms(hs.comm_state))
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 2, 2, 16), 0, 96)
    batch = {"tokens": t, "labels": t, "mask": jnp.ones((2, 2, 2, 16))}
    se, sc = jax.jit(h.step_edge), jax.jit(h.step_cloud)
    hs, m1 = se(hs, batch)
    r1 = res_norms(hs.comm_state)
    assert sum(r1) > 0.0, "EF residual must be nonzero after the edge hop"
    hs, _ = sc(hs, batch)
    r2 = res_norms(hs.comm_state)
    assert r2 != r1, "EF residual must keep evolving on the cloud round's edge hop"
    assert np.isfinite(float(m1["loss"]))

    # --- gossip mix --------------------------------------------------------
    flg = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.01,
                   uplink_compressor="topk:0.01>>qsgd:8", topk_fraction=0.01)
    g = make_gossip_step(model, flg, mesh, chunk=16)
    gs = g.init_fn(jax.random.PRNGKey(0))
    assert gs.comm_state is not None
    gb = {"tokens": t[0], "labels": t[0], "mask": jnp.ones((2, 2, 16))}
    gstep = jax.jit(g.step_fn)
    gs, gm = gstep(gs, gb)
    g1 = res_norms(gs.comm_state)
    assert sum(g1) > 0.0, "EF residual must be nonzero after the gossip mix"
    gs, gm = gstep(gs, gb)
    g2 = res_norms(gs.comm_state)
    assert g2 != g1, "EF residual must keep evolving across mixes"
    assert np.isfinite(float(gm["loss"]))
    print("case_ef_residual_on_edge_hop OK", sum(r1), sum(g1))


def case_kernel_backend_edge_hop():
    """Kernel wire backend (FLConfig.backend="kernel") on the hierarchical
    edge hop: under the biased chained pipeline "topk:0.01>>qsgd:8" the
    kernel-backed EF residuals must evolve identically to pure JAX across
    edge and cloud rounds (the chain's kernel path is deterministic and
    layout padding never leaks into payloads), and so must the per-pod
    params. "Identically" here is the DESIGN.md §6 engine-scope band: the
    pallas_call boundary changes XLA's fusion (FMA contraction) of the
    *surrounding* f32 arithmetic, so single-ULP drift is permitted — the
    nonzero support must still match exactly. Also checks the gossip mix
    for the same spec."""
    cfg = tiny_cfg()
    model = Model(cfg)
    mesh = mesh3()

    def assert_ulp_close(a, b, what):
        a, b = np.asarray(a), np.asarray(b)
        np.testing.assert_array_equal(a == 0, b == 0, err_msg=what)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-8, err_msg=what)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 2, 2, 16), 0, 96)
    batch = {"tokens": t, "labels": t, "mask": jnp.ones((2, 2, 2, 16))}

    def run_hier(backend):
        fl = FLConfig(algorithm="fedavg", local_steps=2,
                      uplink_compressor="topk:0.01>>qsgd:8",
                      topk_fraction=0.01, pod_compressor="qsgd8",
                      hierarchical=True, sync_every=2, backend=backend)
        h = make_hier_fl_train_step(model, fl, mesh, chunk=16)
        hs = h.init_fn(jax.random.PRNGKey(0))
        se, sc = jax.jit(h.step_edge), jax.jit(h.step_cloud)
        hs, _ = se(hs, batch)
        hs, _ = sc(hs, batch)
        return hs

    a, b = run_hier("jax"), run_hier("kernel")
    assert a.comm_state is not None and b.comm_state is not None
    for sa, sb in zip(a.comm_state, b.comm_state):
        for la, lb in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
            assert_ulp_close(la, lb, "hier EF comm_state")
    for la, lb in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        assert_ulp_close(la, lb, "hier params")
    res_norm = sum(float(jnp.abs(l).sum()) for s in b.comm_state
                   for l in jax.tree.leaves(s))
    assert res_norm > 0.0, "kernel-backed EF residual must actually evolve"

    def run_gossip(backend):
        flg = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.01,
                       uplink_compressor="topk:0.01>>qsgd:8",
                       topk_fraction=0.01, backend=backend)
        g = make_gossip_step(model, flg, mesh, chunk=16)
        gs = g.init_fn(jax.random.PRNGKey(0))
        gb = {"tokens": t[0], "labels": t[0], "mask": jnp.ones((2, 2, 16))}
        gs, _ = jax.jit(g.step_fn)(gs, gb)
        return gs

    ga, gb_ = run_gossip("jax"), run_gossip("kernel")
    for la, lb in zip(jax.tree.leaves(ga.comm_state),
                      jax.tree.leaves(gb_.comm_state)):
        assert_ulp_close(la, lb, "gossip EF comm_state")
    for la, lb in zip(jax.tree.leaves(ga.params),
                      jax.tree.leaves(gb_.params)):
        assert_ulp_close(la, lb, "gossip params")
    print("case_kernel_backend_edge_hop OK", res_norm)


def case_pipeline_chain_agg():
    """Tentpole: a chained CommPipeline ("topk:0.01>>qsgd:8") through the
    shard_map aggregator — state (EF residual) threads via FLState.comm_state,
    loss converges, and the chained ledger beats either stage alone."""
    cfg = tiny_cfg()
    model = Model(cfg)
    mesh = mesh2()

    def run(comp, rounds=3, **kw):
        fl = FLConfig(algorithm="fedsgd", local_steps=1, local_lr=0.05,
                      uplink_compressor=comp, topk_fraction=0.01, **kw)
        step = make_fl_train_step(model, fl, mesh, chunk=16)
        state = step.init_fn(jax.random.PRNGKey(0))
        batch = make_batch(cfg, step.n_clients, 2, 16, jax.random.PRNGKey(1))
        jstep = jax.jit(step.step_fn)
        losses = []
        for _ in range(rounds):
            state, m = jstep(state, batch)
            losses.append(float(m["loss_all"]))
        return state, m, losses

    state, m, losses = run("topk:0.01>>qsgd:8")
    assert state.comm_state is not None          # EF residual in pipeline state
    res_norm = sum(float(jnp.abs(a).sum()) for st in state.comm_state
                   for a in jax.tree.leaves(st))
    assert res_norm > 0.0, "EF residual should be nonzero after a round"
    assert all(np.isfinite(losses)) and losses[-1] < losses[0] + 0.05, losses

    chain_wire = float(m["ledger"].uplink_wire)
    topk_wire = float(run("topk", rounds=1)[1]["ledger"].uplink_wire)
    qsgd_wire = float(run("qsgd8", rounds=1)[1]["ledger"].uplink_wire)
    assert chain_wire < topk_wire and chain_wire < qsgd_wire, \
        (chain_wire, topk_wire, qsgd_wire)

    # DGC: momentum-corrected sparsification also threads state end-to-end
    state, m, losses = run("topk", dgc_momentum=0.9)
    assert state.comm_state is not None
    assert all(np.isfinite(losses)), losses
    print("case_pipeline_chain_agg OK",
          {"chain": chain_wire, "topk": topk_wire, "qsgd8": qsgd_wire})


def case_noniid_data_pipeline():
    cfg = FedDataConfig(vocab_size=96, num_clients=8, seq_len=32,
                        batch_per_client=4, heterogeneity=2.0)
    b = sample_round(cfg, jax.random.PRNGKey(0))
    assert b["tokens"].shape == (8, 4, 32)
    assert b["resources"].shape == (8, 4)
    # heterogeneity: client unigram distributions must differ more than iid
    def unigram_dist(toks, V=96):
        return np.bincount(np.asarray(toks).ravel(), minlength=V) / toks.size
    cfg_iid = FedDataConfig(vocab_size=96, num_clients=8, seq_len=32,
                            batch_per_client=4, heterogeneity=0.0)
    b_iid = sample_round(cfg_iid, jax.random.PRNGKey(0))

    def spread(batch):
        ds = np.stack([unigram_dist(batch["tokens"][c]) for c in range(8)])
        return float(np.abs(ds - ds.mean(0)).mean())
    assert spread(b) > 1.5 * spread(b_iid), (spread(b), spread(b_iid))
    print("case_noniid_data_pipeline OK", spread(b), spread(b_iid))


def case_compressed_agg_collectives_in_hlo():
    """The wire claim: compressed aggregation must put int8 (not f32) on the
    client-axis collective."""
    cfg = tiny_cfg()
    model = Model(cfg)
    mesh = mesh2()

    def hlo_for(comp):
        fl = FLConfig(algorithm="fedsgd", uplink_compressor=comp)
        step = make_fl_train_step(model, fl, mesh, chunk=16)
        state = jax.eval_shape(step.init_fn,
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in
                 make_batch(cfg, step.n_clients, 2, 16,
                            jax.random.PRNGKey(1)).items()}
        fn = jax.jit(step.step_fn,
                     in_shardings=(step.state_shardings,
                                   step.batch_sharding_fn(batch)))
        return fn.lower(state, batch).compile().as_text()

    base = hlo_for("none")
    q = hlo_for("qsgd8")
    import re
    def gather_dtypes(txt):
        return set(re.findall(r"(\w+)\[[\d,]*\][^=]*all-gather", txt))
    assert any("s8[" in l and "all-gather" in l for l in q.splitlines()), \
        "int8 payload must be all-gathered"
    assert not any("s8[" in l and "all-gather" in l
                   for l in base.splitlines())
    print("case_compressed_agg_collectives_in_hlo OK")


def case_packed_wire_collectives_in_hlo():
    """The fused-wire claim (DESIGN.md §10): with wire_format='packed' the
    client-axis collective gathers the bit-packed u8 buffer — no s8 or f32
    code plane crosses the wire — and the staged twin still gathers s8."""
    cfg = tiny_cfg()
    model = Model(cfg)
    mesh = mesh2()

    def hlo_for(comp, wire):
        fl = FLConfig(algorithm="fedsgd", uplink_compressor=comp,
                      wire_format=wire)
        step = make_fl_train_step(model, fl, mesh, chunk=16)
        state = jax.eval_shape(step.init_fn,
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in
                 make_batch(cfg, step.n_clients, 2, 16,
                            jax.random.PRNGKey(1)).items()}
        fn = jax.jit(step.step_fn,
                     in_shardings=(step.state_shardings,
                                   step.batch_sharding_fn(batch)))
        return fn.lower(state, batch).compile().as_text()

    packed = hlo_for("ternary", "packed")
    staged = hlo_for("ternary", "staged")
    assert any("u8[" in l and "all-gather" in l for l in packed.splitlines()), \
        "packed payload must be all-gathered as u8"
    assert not any("s8[" in l and "all-gather" in l
                   for l in packed.splitlines()), \
        "no staged s8 code plane may cross the wire when packed"
    assert any("s8[" in l and "all-gather" in l
               for l in staged.splitlines())
    print("case_packed_wire_collectives_in_hlo OK")


def case_population_star_bitexact():
    """Degenerate ClientPopulation contract on the STAR topology (mesh
    client axes, shard_map wire): with cohort == C and capacity >= C the
    store-backed engine must reproduce the dense engine bit-for-bit in
    params AND comm_state (the slab rows ARE the dense rows: slot i <->
    client i, DESIGN.md §9)."""
    from repro.core.engine import Topology, make_round_engine, run_rounds
    from repro.core.population import ClientPopulation

    cfg = tiny_cfg()
    model = Model(cfg)
    mesh = mesh2()
    fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                  uplink_compressor="topk:0.25>>qsgd:8")

    def data_fn(r):
        return make_batch(cfg, 4, 2, 16, jax.random.fold_in(
            jax.random.PRNGKey(1), r))

    outs = []
    for pop in (None, ClientPopulation(n_clients=4, cohort=4, capacity=4)):
        e = make_round_engine(model, fl, Topology.star(), mesh=mesh,
                              chunk=16, population=pop)
        st = e.init_fn(jax.random.PRNGKey(0))
        st, _ = run_rounds(e, st, data_fn, 3, chunk=1, donate=False)
        comm = (st.comm_state["slab"] if isinstance(st.comm_state, dict)
                else st.comm_state)
        outs.append((st.params, comm))
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "population star engine diverged from dense"
    print("case_population_star_bitexact OK")


def case_secagg_masked_bitexact():
    """Masked == unmasked bit-exactly on the multi-device wires (DESIGN.md
    §11): the star shard_map wire (an all_gather of *masked* integer
    payloads — including a packed @fused chain where the masked uint8 planes
    stay uint8 on the collective), the hier edge hop (per-pod mask rings
    over the "data" axis) and the gossip mix (per-edge ppermute of masked
    payloads).  Params, ctx-stripped comm_state and ledger wire bytes must
    all match the unmasked run."""
    from repro.compress.secure_agg import drop_mask_ctx
    from repro.core.engine import Topology, make_round_engine, run_rounds

    cfg = tiny_cfg()
    model = Model(cfg)

    def _eq(tag, a, b):
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        assert len(la) == len(lb), tag
        for x, y in zip(la, lb):
            assert np.array_equal(np.asarray(x), np.asarray(y)), tag

    # --- star shard_map wire ------------------------------------------------
    mesh = mesh2()

    def data_fn(r):
        return make_batch(cfg, 4, 2, 16,
                          jax.random.fold_in(jax.random.PRNGKey(1), r))

    def star_run(spec):
        fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                      uplink_compressor=spec)
        e = make_round_engine(model, fl, Topology.star(), mesh=mesh,
                              chunk=16)
        st = e.init_fn(jax.random.PRNGKey(0))
        st, ms = run_rounds(e, st, data_fn, 3, chunk=1, donate=False)
        return st, ms

    for base in ("topk:0.25>>qsgd:8", "ternary@fused"):
        sb, mb = star_run(base)
        sm, mm = star_run(base + ">>secagg")
        _eq(f"star params {base}", sb.params, sm.params)
        _eq(f"star comm {base}", sb.comm_state,
            drop_mask_ctx(sm.comm_state))
        _eq(f"star ledger {base}", mb["ledger"].uplink_wire,
            mm["ledger"].uplink_wire)

    # --- hier edge hop ------------------------------------------------------
    m3 = mesh3()
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 2, 2, 16), 0, 96)
    hbatch = {"tokens": t, "labels": t, "mask": jnp.ones((2, 2, 2, 16))}

    def hier_run(spec):
        fl = FLConfig(algorithm="fedavg", local_steps=2,
                      uplink_compressor=spec, pod_compressor="qsgd8",
                      hierarchical=True, sync_every=2)
        h = make_hier_fl_train_step(model, fl, m3, chunk=16)
        state = h.init_fn(jax.random.PRNGKey(0))
        se, scl = jax.jit(h.step_edge), jax.jit(h.step_cloud)
        for i in range(3):
            state, _ = (scl if (i + 1) % 2 == 0 else se)(state, hbatch)
        return state

    hb = hier_run("qsgd8")
    hm = hier_run("qsgd8>>secagg")
    _eq("hier params", hb.params, hm.params)
    _eq("hier comm", hb.comm_state, drop_mask_ctx(hm.comm_state))

    # --- gossip mix ---------------------------------------------------------
    def gossip_run(spec):
        flg = FLConfig(algorithm="fedavg", local_steps=1,
                       uplink_compressor=spec, local_lr=0.01)
        g = make_gossip_step(model, flg, m3, chunk=16)
        gs = g.init_fn(jax.random.PRNGKey(0))
        gstep = jax.jit(g.step_fn)
        gb = {"tokens": t[0], "labels": t[0], "mask": jnp.ones((2, 2, 16))}
        for _ in range(3):
            gs, _ = gstep(gs, gb)
        return gs

    gb_ = gossip_run("qsgd8")
    gm_ = gossip_run("qsgd8>>secagg")
    _eq("gossip params", gb_.params, gm_.params)
    _eq("gossip comm", gb_.comm_state, drop_mask_ctx(gm_.comm_state))
    print("case_secagg_masked_bitexact OK")


def case_telemetry_bitexact():
    """Flight-recorder differential on the multi-device wires (DESIGN.md
    §12): telemetry on vs off is bit-exact in params, comm_state, and
    ledger on the star shard_map wire, the hier two-level program, and the
    gossip mix — and the per-stage byte slots reconstruct the ledger wire
    totals exactly in f32 (residual construction).  On hier, ONE
    TelemetrySpec serves both ``lax.cond`` branches: the appended pod slot
    is the residual anchor, landing exactly 0 on edge rounds and exactly
    the cross-pod bytes on cloud rounds."""
    from repro.core.engine import Topology, make_round_engine, run_rounds

    cfg = tiny_cfg()
    model = Model(cfg)

    def _eq(tag, a, b):
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        assert len(la) == len(lb), tag
        for x, y in zip(la, lb):
            assert np.array_equal(np.asarray(x), np.asarray(y)), tag

    def _residual_exact(slots, totals):
        for i in range(slots.shape[0]):
            partial = np.float32(0.0)
            for v in slots[i][:-1]:
                partial = np.float32(partial + np.float32(v))
            assert slots[i][-1] == np.float32(
                np.float32(totals[i]) - partial), (i, slots[i], totals[i])

    def pair(tag, topo_fn, mesh, data_fn, spec, n=4, **fl_kw):
        fl_kw.setdefault("local_lr", 0.2)
        outs = []
        for tele in (False, True):
            fl = FLConfig(algorithm="fedavg", local_steps=1,
                          uplink_compressor=spec, telemetry=tele, **fl_kw)
            e = make_round_engine(model, fl, topo_fn(), mesh=mesh, chunk=16)
            st = e.init_fn(jax.random.PRNGKey(0))
            st, ms = run_rounds(e, st, data_fn, n, chunk=2, donate=False)
            outs.append((st, ms))
        (so, mo), (st_, mt) = outs
        _eq(f"{tag} params", so.params, st_.params)
        _eq(f"{tag} comm_state", so.comm_state, st_.comm_state)
        _eq(f"{tag} ledger", mo["ledger"], mt["ledger"])
        assert "round_stats" not in mo and "round_stats" in mt, tag
        rs = mt["round_stats"]
        _residual_exact(np.asarray(rs.up_stage_bytes),
                        np.asarray(mt["ledger"].uplink_wire))
        _residual_exact(np.asarray(rs.down_stage_bytes),
                        np.asarray(mt["ledger"].downlink_wire))
        return mt

    # --- star shard_map wire ------------------------------------------------
    mesh = mesh2()

    def star_data(r):
        return make_batch(cfg, 4, 2, 16,
                          jax.random.fold_in(jax.random.PRNGKey(1), r))

    pair("star", Topology.star, mesh, star_data, "topk:0.25>>qsgd:8")
    print("  star OK")

    # --- hier two-level program ---------------------------------------------
    m3 = mesh3()
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 2, 2, 16), 0, 96)
    hbatch = {"tokens": t, "labels": t, "mask": jnp.ones((2, 2, 2, 16))}
    mt = pair("hier", lambda: Topology.hier(2), m3, lambda r: hbatch,
              "qsgd8", pod_compressor="qsgd8")
    pod = np.asarray(mt["round_stats"].up_stage_bytes)[:, -1]
    assert pod[0] == 0.0 and pod[2] == 0.0, pod      # edge rounds
    assert pod[1] > 0.0 and pod[1] == pod[3], pod    # cloud rounds
    print("  hier OK (pod slot", pod.tolist(), ")")

    # --- gossip mix -----------------------------------------------------------
    gb = {"tokens": t[0], "labels": t[0], "mask": jnp.ones((2, 2, 16))}
    pair("gossip", Topology.gossip, m3, lambda r: gb, "qsgd8",
         local_lr=0.01)
    print("case_telemetry_bitexact OK")


CASES = {k[5:]: v for k, v in list(globals().items())
         if k.startswith("case_")}

if __name__ == "__main__":
    name = sys.argv[1]
    CASES[name]()
    print("PASS", name)
