"""AsyncEngine tests (DESIGN.md §7).

The headline contract: with constant latencies and ``buffer_size == C`` the
virtual-clock event simulator degenerates to synchronous rounds and must
reproduce the synchronous ``Topology.sim`` FedAvg trajectory **bit-exactly**
(params AND pipeline comm_state), with staleness tau == 0 at every upload.
Plus the genuinely-async invariants: monotone virtual clock, FedBuff flush
cadence, FedAsync (K=1) immediate application, per-event ledger rows with
``virtual_time``, and the configuration guards.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core.async_engine import make_async_step
from repro.core.engine import Topology, make_round_engine, run_rounds
from repro.core.simulate import make_sim_step
from repro.core.types import FLConfig
from repro.data.synthetic import FedDataConfig, sample_round
from repro.models.model import Model

CFG = get_arch("paper_lm")
MODEL = Model(CFG)
C = 4
DATA = FedDataConfig(vocab_size=CFG.vocab_size, num_clients=C, seq_len=32,
                     batch_per_client=2, heterogeneity=1.5)


def _data_fn(r):
    return sample_round(DATA, jax.random.fold_in(jax.random.PRNGKey(1), r))


def _async_engine(fl, buffer_size, profile="constant", alpha=0.5,
                  flush_deadline=None):
    topo = Topology.async_(C, buffer_size=buffer_size,
                           staleness_alpha=alpha, latency_profile=profile,
                           flush_deadline=flush_deadline)
    return make_round_engine(MODEL, fl, topo, chunk=32, data_fn=_data_fn)


def _trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# the equivalence proof: degenerate async == synchronous FedAvg, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["none", "qsgd8", "topk:0.05>>qsgd:8"])
def test_fedbuff_degenerate_matches_sync_bitexact(spec):
    """buffer_size=C + constant latencies: C pops per generation in client
    order, one flush — the identical computation graph to a sync sim round,
    so final params and comm_state match the sync engine bit-for-bit."""
    fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                  uplink_compressor=spec)
    n_gen = 3

    sim = make_sim_step(MODEL, fl, C, chunk=32)
    s_sync, _ = run_rounds(sim.engine, sim.init_fn(jax.random.PRNGKey(0)),
                           _data_fn, n_gen, chunk=2)

    eng = _async_engine(fl, buffer_size=C)
    s_async, ms = run_rounds(eng, eng.init_fn(jax.random.PRNGKey(0)),
                             _data_fn, n_gen * C, chunk=3)

    _trees_equal(s_sync.params, s_async.params)
    if s_sync.comm_state is not None:
        _trees_equal(s_sync.comm_state, s_async.comm_state)
    # ...and the staleness satellite: tau == 0 in this limit, every upload
    assert (np.asarray(ms["staleness"]) == 0.0).all()
    assert int(np.asarray(ms["server_version"])[-1]) == n_gen
    # constant unit latency: the virtual clock counts generations
    np.testing.assert_allclose(np.asarray(ms["clock"])[-1], float(n_gen))


@pytest.mark.parametrize("seed", [0, 3])
def test_degenerate_equivalence_property_over_seeds(seed):
    """Property form of the equivalence: holds for any init seed and for the
    EF-wrapped biased pipeline (per-client residuals threaded through
    delayed completions must evolve exactly like the sync vmapped wire)."""
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.1,
                  uplink_compressor="topk", topk_fraction=0.05, seed=seed)
    sim = make_sim_step(MODEL, fl, C, chunk=32)
    s_sync, _ = run_rounds(sim.engine, sim.init_fn(jax.random.PRNGKey(seed)),
                           _data_fn, 2, chunk=2)
    eng = _async_engine(fl, buffer_size=C)
    s_async, _ = run_rounds(eng, eng.init_fn(jax.random.PRNGKey(seed)),
                            _data_fn, 2 * C, chunk=4)
    _trees_equal(s_sync.params, s_async.params)
    _trees_equal(s_sync.comm_state, s_async.comm_state)
    # EF residual is genuinely nonzero — the equality above is not vacuous
    assert sum(float(jnp.abs(l).sum()) for s in s_async.comm_state
               for l in jax.tree.leaves(s)) > 0.0


@pytest.mark.parametrize("sopt", ["fedadam", "fedyogi"])
def test_degenerate_bitexact_with_staleness_scaled_server_opt(sopt):
    """The staleness-scaled adaptive server optimizers keep the degenerate
    contract: tau == 0 at every flush, so the moment-innovation scale
    (1+tau)^(-alpha) is exactly 1.0 and the async trajectory matches sync
    bit-for-bit — params, comm_state, AND the optimizer moments m/v."""
    fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                  uplink_compressor="qsgd8", server_opt=sopt,
                  server_lr=0.05)
    n_gen = 3
    sim = make_sim_step(MODEL, fl, C, chunk=32)
    s_sync, _ = run_rounds(sim.engine, sim.init_fn(jax.random.PRNGKey(0)),
                           _data_fn, n_gen, chunk=2)
    eng = _async_engine(fl, buffer_size=C)
    s_async, ms = run_rounds(eng, eng.init_fn(jax.random.PRNGKey(0)),
                             _data_fn, n_gen * C, chunk=3)
    _trees_equal(s_sync.params, s_async.params)
    _trees_equal(s_sync.comm_state, s_async.comm_state)
    _trees_equal(s_sync.server_opt_state, s_async.server_opt_state)
    # the moments actually moved — the equality above is not vacuous
    assert sum(float(jnp.abs(l).sum())
               for l in jax.tree.leaves(s_async.server_opt_state["v"])) > 0.0


# ---------------------------------------------------------------------------
# the tentpole contract: ONE shared dispatch body, structurally
# ---------------------------------------------------------------------------

def test_sync_and_async_share_one_dispatch_body(monkeypatch):
    """Regression lock on the PR's structural claim: the async engine has no
    private dispatch mirror — both the sim engine's wire and the async
    engine's generation dispatch are built by ``engine.make_dispatch``, and
    both topologies trace the SAME ``wire_rows`` body."""
    from repro.core import async_engine as amod
    from repro.core import engine as eng

    # the op-for-op mirror of PR 4 is gone
    assert not hasattr(amod, "_dispatch")

    built = []
    real_make = eng.make_dispatch

    def counting_make(*a, **k):
        d = real_make(*a, **k)
        d.wire_calls = 0
        real_rows = d.wire_rows

        def counting_rows(*ra, **rk):
            d.wire_calls += 1
            return real_rows(*ra, **rk)

        d.wire_rows = counting_rows
        built.append(d)
        return d

    monkeypatch.setattr(eng, "make_dispatch", counting_make)
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="qsgd8")
    sim = make_sim_step(MODEL, fl, C, chunk=32)
    aeng = _async_engine(fl, buffer_size=C)
    # one dispatch body per engine build, from the one shared factory
    assert len(built) == 2
    d_sim, d_async = built

    # tracing one sync round invokes the shared wire body...
    state = sim.init_fn(jax.random.PRNGKey(0))
    sim.step_fn(state, _data_fn(jnp.int32(0)))
    assert d_sim.wire_calls >= 1
    # ...and the async init dispatch + one event trace invoke the same body
    # (init runs a full generation-0 dispatch; the event's flush re-traces)
    astate = aeng.init_fn(jax.random.PRNGKey(0))
    assert d_async.wire_calls >= 1
    before = d_async.wire_calls
    jax.jit(aeng.round_fn)(astate, _data_fn(jnp.int32(0)))
    assert d_async.wire_calls > before


# ---------------------------------------------------------------------------
# genuinely-async invariants
# ---------------------------------------------------------------------------


def test_deadline_flush_fires_below_buffer_count():
    """Adaptive buffer sizing (async_flush_deadline): with a K too large to
    ever fill quickly and a short deadline, flushes are time-driven — the
    server stops waiting for stragglers once the deadline lapses — and every
    flush happens at-or-after its deadline tick."""
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="qsgd8")
    n_events = 24
    # K = C means count-flush needs ALL clients; the heavy-tail stragglers
    # make that slow, so the 0.75-deadline does the flushing instead
    eng = _async_engine(fl, buffer_size=C, profile="heavy_tail",
                        flush_deadline=0.75)
    state, ms = run_rounds(eng, eng.init_fn(jax.random.PRNGKey(0)),
                           _data_fn, n_events, chunk=4)
    flushed = np.asarray(ms["flushed"])
    assert flushed.sum() >= 2, "deadline must drive flushes"
    # at least one flush fired below the count threshold (fill < C at pop:
    # buffer_fill reports 0 on flushed events, so check versions advanced
    # faster than C events per flush)
    assert int(np.asarray(ms["server_version"])[-1]) > n_events // C
    # a disabled deadline (the default) keeps pure-count FedBuff semantics
    eng0 = _async_engine(fl, buffer_size=2, profile="heavy_tail")
    _, ms0 = run_rounds(eng0, eng0.init_fn(jax.random.PRNGKey(0)),
                        _data_fn, 8, chunk=4)
    assert np.asarray(ms0["flushed"]).sum() == 4

def test_fedbuff_clock_staleness_and_flush_cadence():
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="qsgd8")
    K, n_events = 2, 16
    eng = _async_engine(fl, buffer_size=K, profile="heavy_tail")
    state, ms = run_rounds(eng, eng.init_fn(jax.random.PRNGKey(0)),
                           _data_fn, n_events, chunk=4)
    clock = np.asarray(ms["clock"])
    assert (np.diff(clock) >= 0).all(), "virtual clock must be monotone"
    assert (np.asarray(ms["staleness"]) >= 0).all()
    # every K-th event flushes: server_version counts flushes
    flushed = np.asarray(ms["flushed"])
    assert flushed.sum() == n_events // K
    assert int(np.asarray(ms["server_version"])[-1]) == n_events // K
    # the per-event ledger carries the virtual clock and ONE client's uplink
    np.testing.assert_allclose(np.asarray(ms["ledger"].virtual_time), clock)
    up = np.asarray(ms["ledger"].uplink_wire)
    np.testing.assert_allclose(up, eng.terms["up_wire"])
    # state is resumable: a second run continues the same event stream
    state2, ms2 = run_rounds(eng, state, _data_fn, 4, chunk=4)
    assert float(np.asarray(ms2["clock"])[0]) >= clock[-1]
    assert int(state2.round) == n_events + 4


def test_fedasync_buffer_one_applies_every_event():
    """K=1 is FedAsync: every completion immediately becomes a server
    update, staleness-decayed by (1+tau)^(-alpha)."""
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2)
    eng = _async_engine(fl, buffer_size=1, profile="uniform", alpha=0.6)
    n_events = 8
    _, ms = run_rounds(eng, eng.init_fn(jax.random.PRNGKey(0)),
                       _data_fn, n_events, chunk=4)
    assert (np.asarray(ms["flushed"]) == 1.0).all()
    assert int(np.asarray(ms["server_version"])[-1]) == n_events
    # under jitter some uploads land on models older than the current one
    assert np.asarray(ms["staleness"]).max() >= 1.0


def test_staleness_decay_downweights_stale_updates():
    """alpha -> large kills stale contributions: with heavy staleness decay
    the aggregated step from a stale-only buffer shrinks. Sanity-check the
    decay arithmetic on the metric stream: (1+tau)^(-alpha) == 1 iff tau==0
    (exactness matters for the degenerate proof)."""
    tau = jnp.arange(4).astype(jnp.float32)
    w = (1.0 + tau) ** (-0.5)
    assert float(w[0]) == 1.0
    assert (np.diff(np.asarray(w)) < 0).all()


def test_make_async_step_convenience():
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  async_buffer_size=2, latency_profile="resource")
    a = make_async_step(MODEL, fl, C, _data_fn, chunk=32)
    assert a.buffer_size == 2
    assert a.engine.aux["latency_profile"] == "resource"
    state = a.init_fn(jax.random.PRNGKey(0))
    state, m = a.step_fn(state, _data_fn(jnp.int32(0)))
    assert state.async_state["clock"].shape == ()
    assert np.isfinite(float(m["loss"]))


# ---------------------------------------------------------------------------
# configuration guards
# ---------------------------------------------------------------------------

def test_async_guards():
    fl = FLConfig(algorithm="scaffold", local_steps=2)
    with pytest.raises(ValueError, match="fedavg/fedsgd/fedprox"):
        _async_engine(fl, buffer_size=C)
    fl = FLConfig(selection="random", clients_per_round=2)
    with pytest.raises(ValueError, match="completion order"):
        _async_engine(fl, buffer_size=C)
    with pytest.raises(ValueError, match="buffer_size"):
        _async_engine(FLConfig(), buffer_size=C + 1)
    with pytest.raises(ValueError, match="latency profile"):
        _async_engine(FLConfig(), buffer_size=C, profile="nope")
    with pytest.raises(ValueError, match="data_fn"):
        make_round_engine(MODEL, FLConfig(), Topology.async_(C), chunk=32)
