"""Per-assigned-architecture smoke tests (deliverable f).

Each instantiates the REDUCED variant of the same family (2 superblocks,
d_model<=256, <=4 experts) and runs one forward/train step plus one decode
step on CPU, asserting output shapes and no NaNs. The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation) — but their
exact assigned hyperparameters are asserted here against the assignment
table.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_arch, get_smoke
from repro.models.model import Model

ASSIGNED = [a for a in ARCH_IDS if a != "paper_lm"]

# the assignment table (arch -> (L, d_model, H, kv, d_ff, vocab, experts, topk))
TABLE = {
    "qwen2_5_32b": (64, 5120, 40, 8, 27648, 152064, 0, 0),
    "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048, 16, 1),
    "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 768, 151936, 128, 8),
    "mamba2_370m": (48, 1024, 0, 0, 0, 50280, 0, 0),
    "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163840, 64, 6),
    "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536, 16, 2),
    "whisper_base": (6, 512, 8, 8, 2048, 51865, 0, 0),
    "llama3_2_1b": (16, 2048, 32, 8, 8192, 128256, 0, 0),
    "internvl2_76b": (80, 8192, 64, 8, 28672, 128256, 0, 0),
    "deepseek_67b": (95, 8192, 64, 8, 22016, 102400, 0, 0),
}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    cfg = get_arch(arch)
    L, d, H, kv, ff, V, E, K = TABLE[arch]
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == H and cfg.num_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == V
    assert cfg.num_experts == E and cfg.experts_per_token == K
    assert cfg.citation


def _smoke_batch(cfg, B=2, S=16):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.num_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    assert cfg.num_layers <= 2 * len(cfg.block_pattern)
    assert cfg.d_model <= 512 and (cfg.num_experts or 0) <= 4
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: m.loss(p, batch, chunk=8), has_aux=True)(params)
    assert bool(jnp.isfinite(loss)), arch
    # one SGD step reduces nothing catastrophic: params finite
    new = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    for leaf in jax.tree.leaves(new):
        assert bool(jnp.isfinite(leaf).all()), arch
    loss2, _ = m.loss(new, batch, chunk=8)
    assert bool(jnp.isfinite(loss2)) and float(loss2) < float(loss) + 1.0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B = 2
    enc_len = cfg.frontend_tokens if cfg.family == "encdec" else 0
    cache = m.init_cache(B, 8, enc_len=enc_len)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c, t, pos: m.decode(p, c, t, pos))(params, cache, tok,
                                                     jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size), arch
    assert bool(jnp.isfinite(logits).all()), arch
    # cache structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)
