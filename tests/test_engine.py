"""RoundEngine tests: the scan driver (run_rounds) matches the Python round
loop bit-for-bit, compiles once per chunk shape, the topology bindings expose
the canonical hop sequence, and the DGC warm-up schedule anneals the
effective top-k fraction as configured."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core.engine import (RoundRunner, Topology, check_doubly_stochastic,
                               erdos_renyi_graph, expander_graph,
                               make_round_engine, mixing_matrix, run_rounds,
                               uplink_pipeline)
from repro.core.simulate import make_sim_step
from repro.core.types import FLConfig
from repro.data.synthetic import FedDataConfig, sample_round
from repro.models.model import Model

CFG = get_arch("paper_lm")
MODEL = Model(CFG)
DATA = FedDataConfig(vocab_size=CFG.vocab_size, num_clients=4, seq_len=32,
                     batch_per_client=2, heterogeneity=1.5)


def _data_fn(r):
    return sample_round(DATA, jax.random.fold_in(jax.random.PRNGKey(1), r))


def _sim(fl):
    return make_sim_step(MODEL, fl, DATA.num_clients, chunk=32)


# ---------------------------------------------------------------------------
# scan driver == Python loop
# ---------------------------------------------------------------------------

def test_run_rounds_matches_python_loop():
    """The acceptance contract: run_rounds (scan) must produce the identical
    final params as stepping the same round_fn in a Python loop for a fixed
    seed (paper_lm workload)."""
    fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                  uplink_compressor="topk:0.05>>qsgd:8")
    sim = _sim(fl)
    n = 5

    state_l = sim.init_fn(jax.random.PRNGKey(0))
    for r in range(n):
        state_l, m_l = sim.step_fn(state_l, _data_fn(jnp.int32(r)))

    state_s, ms = run_rounds(sim.engine, sim.init_fn(jax.random.PRNGKey(0)),
                             _data_fn, n, chunk=3)    # 3 + 2: two chunk shapes
    for a, b in zip(jax.tree.leaves(state_l.params),
                    jax.tree.leaves(state_s.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # comm_state (EF residual of the chained pipeline) matches too
    for a, b in zip(jax.tree.leaves(state_l.comm_state),
                    jax.tree.leaves(state_s.comm_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # metrics are stacked over the round dim, ledger included
    assert ms["loss"].shape == (n,)
    assert ms["ledger"].uplink_wire.shape == (n,)
    assert float(ms["ledger"].uplink_wire[0]) == \
        pytest.approx(float(m_l["ledger"].uplink_wire))


def test_run_rounds_single_compile_per_chunk_shape():
    """2 full chunks reuse ONE compiled scan; a trailing partial chunk adds
    exactly one more compilation."""
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="qsgd8")
    sim = _sim(fl)
    runner = RoundRunner(sim.engine, _data_fn, chunk=2)
    state = sim.init_fn(jax.random.PRNGKey(0))
    state, ms = runner.run(state, 4)          # 2 chunks, same shape
    assert ms["loss"].shape == (4,)
    size = runner.cache_size()
    if size is None:
        pytest.skip("jit cache introspection unavailable on this jax")
    assert size == 1, f"expected one compilation for two equal chunks, got {size}"
    state, _ = runner.run(state, 3)           # 2 + 1: one new shape
    assert runner.cache_size() == 2


def test_round_index_threaded_to_data_fn():
    """data_fn receives state.round — chunk boundaries must not reset it."""
    seen = []

    def data_fn(r):
        # traced; record via shape-free identity on the host at trace time
        return _data_fn(r)

    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2)
    sim = _sim(fl)
    state = sim.init_fn(jax.random.PRNGKey(0))
    state, _ = run_rounds(sim.engine, state, data_fn, 4, chunk=2)
    assert int(state.round) == 4
    state, _ = run_rounds(sim.engine, state, data_fn, 2, chunk=2)
    assert int(state.round) == 6


def test_eval_cadence_skips_evals_without_perturbing_params():
    """FLConfig.eval_every gates metrics_fn behind a cond: changing the
    cadence must not change the training trajectory (final params bitwise
    identical), skipped rounds NaN-fill only the eval-only leaves, and the
    base round metrics (loss, ledger) survive every round."""
    from repro.data.synthetic import eval_batch
    ev = eval_batch(DATA, jax.random.PRNGKey(99), batch_size=2)

    def metrics_fn(state, m):
        return dict(m, eval_loss=MODEL.loss(state.params, ev, chunk=32)[0])

    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="qsgd8", eval_every=3)
    sim = _sim(fl)
    assert sim.engine.eval_every == 3       # threaded from FLConfig

    n = 6
    s1, m1 = run_rounds(sim.engine, sim.init_fn(jax.random.PRNGKey(0)),
                        _data_fn, n, chunk=3, metrics_fn=metrics_fn)
    s2, m2 = run_rounds(sim.engine, sim.init_fn(jax.random.PRNGKey(0)),
                        _data_fn, n, chunk=3, metrics_fn=metrics_fn,
                        eval_every=1)       # override: eval every round
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    ev1 = np.asarray(m1["eval_loss"])
    ev2 = np.asarray(m2["eval_loss"])
    # cadence 3 evaluates the last round of each window (rounds 2 and 5)
    assert np.isfinite(ev1[[2, 5]]).all()
    assert np.isnan(ev1[[0, 1, 3, 4]]).all()
    np.testing.assert_array_equal(ev1[[2, 5]], ev2[[2, 5]])
    # base metrics survive skipped rounds (only eval-only leaves are gated)
    assert np.isfinite(np.asarray(m1["loss"])).all()
    assert np.isfinite(np.asarray(m1["ledger"].uplink_wire)).all()


# ---------------------------------------------------------------------------
# topology bindings and the hop contract
# ---------------------------------------------------------------------------

def test_sim_program_hop_sequence():
    fl = FLConfig(algorithm="fedavg", local_steps=1,
                  uplink_compressor="topk", topk_fraction=0.05)
    eng = make_round_engine(MODEL, fl, Topology.sim(4), chunk=32)
    names = eng.program.hop_names
    # the canonical hop order: local-update -> wire -> server-opt -> ledger
    for a, b in [("local_update", "wire"), ("wire", "server_opt"),
                 ("server_opt", "ledger"), ("ledger", "finalize")]:
        assert names.index(a) < names.index(b), names
    assert eng.topology.kind == "sim"


def test_sim_only_hops_gated():
    cm = FLConfig(algorithm="fedavg", local_steps=1, cmfl_threshold=0.5)
    eng = make_round_engine(MODEL, cm, Topology.sim(4), chunk=32)
    assert "cmfl" in eng.program.hop_names
    sc = FLConfig(algorithm="scaffold", local_steps=2)
    eng = make_round_engine(MODEL, sc, Topology.sim(4), chunk=32)
    assert "control" in eng.program.hop_names


def test_topology_factories():
    assert Topology.star().kind == "star"
    assert Topology.hier(3).sync_every == 3
    assert Topology.sim(7).n_clients == 7
    g = Topology.gossip([(2, 0.5)])
    assert g.graph == ((2, 0.5),)
    a = Topology.async_(8, buffer_size=4, staleness_alpha=0.3,
                        latency_profile="heavy_tail")
    assert (a.kind, a.n_clients, a.buffer_size) == ("async", 8, 4)
    with pytest.raises(ValueError):
        make_round_engine(MODEL, FLConfig(), Topology(kind="mesh"), chunk=32)


# ---------------------------------------------------------------------------
# gossip graphs beyond rings: expander / Erdős–Rényi + doubly-stochastic check
# ---------------------------------------------------------------------------

def test_ring_mixing_matrix_is_classic():
    """The default symmetric ring is W = I/2 + (L+R)/4."""
    W = mixing_matrix(((1, 0.25), (-1, 0.25)), 4)
    check_doubly_stochastic(W)
    expect = np.eye(4) * 0.5 + 0.25 * (np.roll(np.eye(4), 1, 0)
                                       + np.roll(np.eye(4), -1, 0))
    np.testing.assert_allclose(W, expect)


@pytest.mark.parametrize("n", [4, 8, 12])
def test_expander_graph_doubly_stochastic_and_mixes_faster(n):
    g = expander_graph(n, degree=4)
    W = mixing_matrix(g, n)
    check_doubly_stochastic(W)
    ring = mixing_matrix(((1, 0.25), (-1, 0.25)), n)
    lam2 = lambda M: np.sort(np.abs(np.linalg.eigvals(M)))[-2]
    if n >= 8:    # same degree-2 graph at n=4
        assert lam2(W) < lam2(ring) + 1e-9, (lam2(W), lam2(ring))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_erdos_renyi_graph_doubly_stochastic(seed):
    n = 10
    g = erdos_renyi_graph(n, p=0.5, seed=seed)
    W = mixing_matrix(g, n)
    check_doubly_stochastic(W)
    # symmetric (matchings with a uniform Metropolis-style weight)
    np.testing.assert_allclose(W, W.T)
    # each entry is a full permutation tuple (ppermute-able matching)
    for perm, w in g:
        assert sorted(perm) == list(range(n))
        assert all(perm[perm[i]] == i for i in range(n))   # involution


def test_gossip_graph_doubly_stochastic_check_rejects():
    # overweight incoming edges -> negative self-weight
    with pytest.raises(ValueError, match="negative"):
        check_doubly_stochastic(mixing_matrix(((1, 0.8), (-1, 0.8)), 8))
    # a non-permutation entry fails loudly at edge construction
    with pytest.raises(ValueError, match="permutation"):
        mixing_matrix((((0, 0, 1, 2), 0.25),), 4)
    # the engine builder runs the check on every graph (single-device mesh:
    # C=1 collapses every ring to a self-loop, which is legitimately doubly
    # stochastic, so exercise the C>1 path through mixing_matrix directly)
    W = mixing_matrix(Topology.gossip_expander(8, 4).graph, 8)
    check_doubly_stochastic(W)


# ---------------------------------------------------------------------------
# DGC warm-up sparsity schedule
# ---------------------------------------------------------------------------

def test_dgc_warmup_fraction_anneals():
    """With dgc_warmup_rounds=W the effective transmitted fraction follows
    f_r = target^((r+1)/(W+1)): near-dense early, the target after warm-up."""
    n, W, target = 4096, 3, 0.01
    fl = FLConfig(uplink_compressor="topk", topk_fraction=target,
                  dgc_momentum=0.9, dgc_warmup_rounds=W)
    pipe = uplink_pipeline(fl)
    assert pipe.stateful
    st = pipe.init((n,))
    assert "round" in st

    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    fracs = []
    for t in range(W + 3):
        payload, st = pipe.encode(st, jax.random.PRNGKey(t), x)
        dec = pipe.decode(payload, n)
        fracs.append(float((dec != 0).mean()))
    expect = [target ** (min(r + 1, W + 1) / (W + 1.0)) for r in range(W + 3)]
    for got, want in zip(fracs, expect):
        assert got == pytest.approx(want, rel=0.1, abs=2.0 / n), (fracs, expect)
    # strictly annealing down to the target during warm-up
    assert all(a > b for a, b in zip(fracs[:W], fracs[1:W + 1])), fracs
    assert fracs[-1] == pytest.approx(target, rel=0.1)
    # wire accounting is static at the warm-up (widest) capacity
    inner_frac = target ** (1.0 / (W + 1.0))
    from repro.compress import make_compressor
    inner = make_compressor("topk", fraction=inner_frac)
    assert pipe.wire_bits(n) == inner.wire_bits(n)


def test_dgc_warmup_rejects_fraction_frozen_specs():
    """Specs whose per-stage fraction overrides the kwarg (so the warm-up
    widening could never reach the wire) must fail loudly, not silently
    transmit the target fraction from round 0."""
    for spec in ("topk:0.01", "topk:0.01>>qsgd:8", "qsgd8"):
        fl = FLConfig(uplink_compressor=spec, topk_fraction=0.01,
                      dgc_momentum=0.9, dgc_warmup_rounds=3)
        with pytest.raises(ValueError, match="warm-up"):
            uplink_pipeline(fl)
    # fraction-kwarg-driven chain forms do warm up
    fl = FLConfig(uplink_compressor="topk>>qsgd:8", topk_fraction=0.01,
                  dgc_momentum=0.9, dgc_warmup_rounds=3)
    assert uplink_pipeline(fl).name.endswith("@warmup3")


def test_gossip_rejects_dgc_momentum():
    """DGC accumulates update deltas; the gossip mix ships raw model
    parameters (accumulating those diverges) — must fail loudly."""
    from repro.core.compat import make_mesh
    mesh = make_mesh((jax.device_count(),), ("data",))
    fl = FLConfig(uplink_compressor="topk", topk_fraction=0.05,
                  dgc_momentum=0.9)
    with pytest.raises(ValueError, match="gossip"):
        make_round_engine(MODEL, fl, Topology.gossip(), mesh=mesh, chunk=32)


def test_dgc_warmup_off_is_plain_dgc():
    fl = FLConfig(uplink_compressor="topk", topk_fraction=0.05,
                  dgc_momentum=0.9)
    pipe = uplink_pipeline(fl)
    st = pipe.init((128,))
    assert "round" not in st
    assert pipe.name.startswith("mc0.9")


def test_dgc_warmup_through_sim_engine():
    """End-to-end: the annealed pipeline threads through FLState.comm_state
    and the per-round nnz of the decoded aggregate shrinks over warm-up."""
    fl = FLConfig(algorithm="fedsgd", local_steps=1, local_lr=0.1,
                  uplink_compressor="topk", topk_fraction=0.02,
                  dgc_momentum=0.9, dgc_warmup_rounds=2)
    sim = _sim(fl)
    state = sim.init_fn(jax.random.PRNGKey(0))
    state, ms = run_rounds(sim.engine, state, _data_fn, 4, chunk=4)
    assert state.comm_state is not None
    # every per-leaf state carries the warm-up round counter at 4
    counters = [np.asarray(a) for s in state.comm_state
                for a in jax.tree.leaves(s)
                if np.asarray(a).dtype == np.int32]
    assert counters and all((c == 4).all() for c in counters)
    assert np.isfinite(np.asarray(ms["loss"])).all()
