"""The claims registry (benchmarks/claims.py) is the single source of truth
for every measured ``holds=`` claim: prose (EXPERIMENTS.md), emitted rows
(benchmarks/run.py), and the committed BENCH_<pr>.json trajectory must all
resolve against it.  Three ways a claim can exist, three cross-checks:

  * quoted in EXPERIMENTS.md   -> must be registered (id + reproduce +
    tolerance), so the prose cannot cite a claim nobody re-measures;
  * emitted by benchmarks/run.py -> must be registered, so a new holds=
    row cannot ship without a reproduce command (also enforced at runtime
    by _check_trajectory before writing a BENCH json);
  * recorded in BENCH_*.json   -> must be registered, so the trajectory
    back-catalog stays re-checkable.
"""
import glob
import importlib.util
import json
import os
import re
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load(name, relpath):
    path = os.path.join(ROOT, relpath)
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod        # dataclasses resolves cls.__module__
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def registry():
    return _load("bench_claims", os.path.join("benchmarks", "claims.py"))


# --- prose -> registry ------------------------------------------------------

_QUOTED_CLAIM = re.compile(r"`([a-z0-9_.]+/claim_[a-z0-9_./*]+)`")


def _experiments_claims():
    with open(os.path.join(ROOT, "EXPERIMENTS.md")) as fh:
        return sorted(set(_QUOTED_CLAIM.findall(fh.read())))


def test_experiments_quotes_claims():
    assert len(_experiments_claims()) >= 5, (
        "claim-id extraction from EXPERIMENTS.md rotted")


@pytest.mark.parametrize("name", _experiments_claims())
def test_every_experiments_claim_is_registered(name, registry):
    # `fused/claim_ledger_eq_hlo/*` cites the whole parametrised family
    probe = name[:-2] if name.endswith("/*") else name
    assert registry.lookup(probe) is not None, (
        f"EXPERIMENTS.md cites {name!r} but benchmarks/claims.py has no "
        f"Claim for it — register id + reproduce + tolerance")


# --- emitted rows -> registry ----------------------------------------------

def _runpy_claims():
    with open(os.path.join(ROOT, "benchmarks", "run.py")) as fh:
        src = fh.read()
    out = set()
    for m in re.finditer(r'emit\(f?"([a-z0-9_.]+/claim_[^"{]*)', src):
        out.add(m.group(1).rstrip("/"))
    return sorted(out)


def test_runpy_emits_claims():
    assert len(_runpy_claims()) >= 8


@pytest.mark.parametrize("name", _runpy_claims())
def test_every_emitted_claim_is_registered(name, registry):
    assert registry.lookup(name) is not None, (
        f"benchmarks/run.py emits {name!r} with no Claim entry in "
        f"benchmarks/claims.py")


# --- trajectory back-catalog -> registry ------------------------------------

def _bench_json_claims():
    out = []
    for p in sorted(glob.glob(os.path.join(ROOT, "benchmarks",
                                           "BENCH_*.json"))):
        with open(p) as fh:
            for c in json.load(fh).get("claims", []):
                out.append(pytest.param(
                    os.path.basename(p), c["name"],
                    id=f"{os.path.basename(p)}:{c['name']}"))
    return out


@pytest.mark.parametrize("src,name", _bench_json_claims())
def test_every_recorded_claim_is_registered(src, name, registry):
    assert registry.lookup(name) is not None, (
        f"{src} records claim {name!r} unknown to benchmarks/claims.py")


# --- registry self-consistency ----------------------------------------------

def test_registry_ids_unique(registry):
    ids = [c.id for c in registry.REGISTRY]
    assert len(ids) == len(set(ids))


def test_registry_suites_exist(registry):
    run = _load("benchmarks_run", os.path.join("benchmarks", "run.py"))
    for c in registry.REGISTRY:
        assert c.suite in run.BENCHES, (
            f"{c.id}: suite {c.suite!r} is not a registered benchmark")
        assert f"--only {c.suite}" in c.reproduce, (
            f"{c.id}: reproduce command does not run its own suite")
        assert c.tolerance and c.description


def test_smoke_suites_cover_ci_recheck(registry):
    # the claims-recheck CI job re-runs exactly these; privacy, scale and
    # fused carry deterministic (bitwise / inequality) predicates that
    # must stay smoke-checkable
    suites = registry.smoke_suites()
    for s in ("privacy", "scale", "fused"):
        assert s in suites


def test_lookup_resolves_family_rows(registry):
    assert registry.lookup("fused/claim_ledger_eq_hlo/ternary") is not None
    assert registry.lookup("fused/claim_packed_shrinks_wire/stc:0.1") \
        is not None
    assert registry.lookup("fused/claim_nonexistent") is None
    assert registry.unregistered(["privacy/claim_masked_bitexact",
                                  "bogus/claim_x"]) == ["bogus/claim_x"]
