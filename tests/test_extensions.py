"""FedDANE [49], CMFL [35], FL+HC [43] — the remaining surveyed techniques."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core.clustering import (adjusted_match, agglomerate,
                                   pairwise_delta_distance)
from repro.core.simulate import make_sim_step
from repro.core.types import FLConfig
from repro.data.synthetic import FedDataConfig, sample_round
from repro.models.model import Model


def _run(fl, rounds=4, clients=6, seed=0):
    cfg = get_arch("paper_lm")
    model = Model(cfg)
    dcfg = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=clients,
                         seq_len=32, batch_per_client=2, heterogeneity=1.5,
                         seed=seed)
    sim = make_sim_step(model, fl, clients, chunk=32)
    state = sim.init_fn(jax.random.PRNGKey(seed))
    ms = []
    for r in range(rounds):
        b = sample_round(dcfg, jax.random.fold_in(jax.random.PRNGKey(1), r))
        state, m = sim.step_fn(state, b)
        ms.append(m)
    return state, ms


def test_feddane_converges_and_pays_double_wire():
    fl = FLConfig(algorithm="feddane", local_steps=3, local_lr=0.1,
                  fedprox_mu=0.01)
    state, ms = _run(fl)
    losses = [float(m["loss_all"]) for m in ms]
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    # the gradient-exchange round doubles the accounted uplink
    fl0 = FLConfig(algorithm="fedavg", local_steps=3, local_lr=0.1)
    _, ms0 = _run(fl0, rounds=1)
    assert float(ms[0]["ledger"].uplink_wire) == \
        2 * float(ms0[0]["ledger"].uplink_wire)


def test_feddane_quadratic_beats_fedavg_drift():
    """On the heterogeneous-quadratic drift construction, DANE's gradient
    correction (like SCAFFOLD's control variates) removes the FedAvg bias."""
    from repro.core.federated import _client_update
    d, C = 8, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    Q = jax.random.normal(ks[0], (C, d, d))
    A = jnp.einsum("cij,ckj->cik", Q, Q) / d + 0.1 * jnp.eye(d)
    b = jax.random.normal(ks[1], (C, d)) * 3.0
    wstar = jnp.linalg.solve(A.sum(0), jnp.einsum("cij,cj->i", A, b))

    class QuadModel:
        def loss(self, p, batch, chunk=0):
            r = p["w"] - batch["b"]
            return 0.5 * r @ batch["A"] @ r, {}

    def run(algo, R=80, lr=0.05, E=10):
        fl = FLConfig(algorithm=algo, local_steps=E, local_lr=lr,
                      fedprox_mu=0.0)
        params = {"w": jnp.zeros(d)}
        for _ in range(R):
            gg = None
            if algo == "feddane":
                g_each = jax.vmap(lambda bA, bb: jax.grad(
                    lambda p: QuadModel().loss(p, {"A": bA, "b": bb})[0])(
                    params))(A, b)
                gg = jax.tree.map(lambda g: g.mean(0), g_each)
            deltas, _, _, _ = jax.vmap(
                lambda bA, bb: _client_update(
                    QuadModel(), fl, params, {"A": bA, "b": bb},
                    jax.random.PRNGKey(0), None, None, 0, global_grad=gg))(
                A, b)
            params = jax.tree.map(lambda p, g: p + g.mean(0), params, deltas)
        return float(jnp.linalg.norm(params["w"] - wstar))

    e_avg, e_dane = run("fedavg"), run("feddane")
    assert e_dane < 0.05 * e_avg, (e_avg, e_dane)


def test_cmfl_filters_irrelevant_updates():
    fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.1,
                  cmfl_threshold=0.52)
    state, ms = _run(fl, rounds=5)
    sel = [float(m["selected"]) for m in ms]
    assert sel[0] == 6.0                      # warm-up round: everyone
    assert any(s < 6.0 for s in sel[1:]), sel # filtering kicks in
    losses = [float(m["loss_all"]) for m in ms]
    assert np.isfinite(losses[-1])


def test_flhc_recovers_generator_clusters():
    """FL+HC [43]: clustering clients by update similarity recovers the
    synthetic corpus's ground-truth generator clusters."""
    # build per-client deltas from one FedAvg round at high heterogeneity
    cfg = get_arch("paper_lm")
    model = Model(cfg)
    C = 8
    dcfg = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=C,
                         seq_len=32, batch_per_client=4, heterogeneity=6.0,
                         client_skew=0.0, num_clusters=2, seed=3)
    from repro.core.federated import _client_update
    from repro.data.synthetic import client_clusters
    fl = FLConfig(algorithm="fedavg", local_steps=4, local_lr=0.3)
    params = model.init(jax.random.PRNGKey(0))
    # a couple of warm-up aggregate rounds sharpen the update directions
    for r in range(2):
        b = sample_round(dcfg, jax.random.fold_in(jax.random.PRNGKey(4), r))
        deltas, _, _, _ = jax.vmap(
            lambda tok, lab, msk: _client_update(
                model, fl, params,
                {"tokens": tok, "labels": lab, "mask": msk},
                jax.random.PRNGKey(0), None, None, 32))(
            b["tokens"], b["labels"], b["mask"])
        params = jax.tree.map(
            lambda p, d: (p + d.mean(0)).astype(p.dtype), params, deltas)
    flat = np.concatenate(
        [np.asarray(l.reshape(C, -1), np.float32)
         for l in jax.tree.leaves(deltas)], axis=1)
    D = pairwise_delta_distance(flat, metric="cosine")
    labels = agglomerate(D, threshold=float(np.median(D)))
    truth = np.asarray(client_clusters(dcfg))
    score = adjusted_match(labels, truth)
    assert score >= 0.7, (labels, truth, score)


def test_agglomerate_basic():
    D = np.array([[0, .1, .9, .9], [.1, 0, .9, .9],
                  [.9, .9, 0, .1], [.9, .9, .1, 0]])
    labels = agglomerate(D, threshold=0.5)
    assert labels[0] == labels[1] and labels[2] == labels[3]
    assert labels[0] != labels[2]
    assert adjusted_match(labels, np.array([0, 0, 1, 1])) == 1.0
