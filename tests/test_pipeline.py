"""CommPipeline API tests: composition round-trips, the wire-bit composition
law, spec-string parsing, backward-compat of legacy registry names, and the
stateful wrapping transforms (error feedback / DGC momentum correction)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import (Identity, chain, error_feedback,
                            make_compressor, momentum_correction)
from repro.compress.pipeline import Chain
from repro.compress.quantization import QSGD
from repro.compress.sparsification import Ternary, TopK


def _x(seed, n, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale


def _tree(seed, shapes):
    ks = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return {f"leaf{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


# ---------------------------------------------------------------------------
# composition round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    "topk:0.01>>qsgd:8",
    "topk:0.1>>ternary",
    "randmask:0.1>>qsgd:8",
    "sketch>>qsgd:8",
    "topk:0.05>>qsgd:4",
])
def test_chain_roundtrip_random_pytrees(spec):
    pipe = make_compressor(spec, cols=256)
    tree = _tree(0, [(1000,), (64, 32), (7, 11, 3)])
    for i, leaf in enumerate(jax.tree.leaves(tree)):
        flat = leaf.reshape(-1)
        y = pipe.roundtrip(jax.random.fold_in(jax.random.PRNGKey(1), i), flat)
        assert y.shape == flat.shape
        assert y.dtype == jnp.float32
        assert bool(jnp.isfinite(y).all()), spec


def test_chain_topk_qsgd_approximates_topk():
    """The quantized-sparse chain must stay close to plain top-k: support is
    identical and values differ by at most the QSGD block bound."""
    n = 4096
    x = _x(0, n, 2.0)
    topk = make_compressor("topk", fraction=0.05)
    pipe = make_compressor("topk:0.05>>qsgd:8")
    y_topk = np.asarray(topk.roundtrip(jax.random.PRNGKey(1), x))
    y_pipe = np.asarray(pipe.roundtrip(jax.random.PRNGKey(1), x))
    assert ((y_topk != 0) == (y_pipe != 0)).all()       # same support
    bound = np.abs(y_topk).max() / 127 + 1e-5           # QSGD per-block bound
    assert np.abs(y_pipe - y_topk).max() <= bound


def test_chain_stc_equals_legacy_stc_semantics():
    """'stc' resolves to chain(topk, ternary) and keeps the monolithic
    compressor's exact reconstruction: ternary levels on the top-k support."""
    pipe = make_compressor("stc", fraction=0.1)
    assert isinstance(pipe, Chain)
    x = _x(4, 1000, 3.0)
    y = np.asarray(pipe.roundtrip(jax.random.PRNGKey(0), x))
    nz = y[y != 0]
    assert len(np.unique(np.abs(nz))) == 1              # single magnitude mu
    k = 100
    mag = np.sort(np.abs(np.asarray(x)))[-k:]
    np.testing.assert_allclose(np.abs(nz)[0], mag.mean(), rtol=1e-5)


def test_identity_is_chain_unit():
    q = QSGD(8)
    assert chain(Identity(), q) is q
    assert chain(q, ) is q
    assert chain(Identity(), Identity()).is_identity
    c = chain(TopK(0.1), chain(Identity(), Ternary()))
    assert isinstance(c, Chain) and len(c.stages) == 2


def test_terminal_stage_cannot_be_chained():
    with pytest.raises(ValueError):
        chain(QSGD(8), TopK(0.1))       # qsgd has no carrier


# ---------------------------------------------------------------------------
# wire-bit composition law
# ---------------------------------------------------------------------------

def test_wire_bits_composition_law():
    """On sparse supports the chained wire is strictly below either stage
    alone, and equals meta(topk) + qsgd's bits on the k-length carrier."""
    n = 1 << 20
    topk = make_compressor("topk", fraction=0.01)
    qsgd = make_compressor("qsgd8")
    pipe = make_compressor("topk:0.01>>qsgd:8")
    k = max(1, round(n * 0.01))
    assert pipe.wire_bits(n) == topk.meta_bits(n) + qsgd.wire_bits(k)
    assert pipe.wire_bits(n) < topk.wire_bits(n)
    assert pipe.wire_bits(n) < qsgd.wire_bits(n)
    assert pipe.entropy_bits(n) <= pipe.wire_bits(n)


def test_wire_bits_legacy_names_unchanged():
    """Every pre-pipeline registry name must report the pre-pipeline wire/
    entropy formulas, bit for bit (hard-coded from the flat-class era)."""
    for n in (1 << 10, 1 << 16, 1 << 20):
        nb = -(-n // 2048)
        k1 = max(1, round(n * 0.01))
        k5 = max(1, round(n * 0.05))
        idx1 = math.log2(max(n / k1, 2.0)) + 2
        legacy = [
            ("none", {}, 32.0 * n, 32.0 * n),
            ("qsgd8", {}, 8.0 * n + 32.0 * nb, 8.0 * n + 32.0 * nb),
            ("qsgd4", {}, 8.0 * n + 32.0 * nb, 5.0 * n + 32.0 * nb),
            ("lfl8", {}, 8.0 * n + 32.0 * nb, 8.0 * n + 32.0 * nb),
            ("uveq", {}, 8.0 * n + 32.0 * nb + 32.0,
             4.0 * n + 32.0 * nb + 32.0),
            ("hsq", {}, 8.0 * n + 32.0 * nb, 1.0 * n + 32.0 * nb),
            ("topk", dict(fraction=0.01), k1 * 64.0, k1 * (32.0 + idx1)),
            ("stc", dict(fraction=0.01), k1 * 40.0 + 32.0,
             k1 * (idx1 + 1.0) + 32.0),
            ("sbc", dict(fraction=0.01), k1 * 32.0 + 32.0,
             k1 * idx1 + 32.0),
            ("randmask", dict(fraction=0.05), k5 * 32.0 + 64.0,
             k5 * 32.0 + 64.0),
        ]
        for name, kw, wire, ent in legacy:
            comp = make_compressor(name, **kw)
            assert comp.wire_bits(n) == pytest.approx(wire), (name, n)
            assert comp.entropy_bits(n) == pytest.approx(ent), (name, n)
    # sketch: width adapts to n
    comp = make_compressor("sketch", rows=5, cols=512)
    n = 1 << 16
    cols = int(min(512, max(8, n // 10)))
    assert comp.wire_bits(n) == 32.0 * 5 * cols


# ---------------------------------------------------------------------------
# spec-string parsing
# ---------------------------------------------------------------------------

def test_spec_parsing():
    p = make_compressor("topk:0.01>>qsgd:8")
    assert p.name == "topk0.01>>qsgd8"
    assert make_compressor("qsgd:4,128").block == 128
    assert make_compressor("topk:0.02").fraction == 0.02
    # kwargs supply defaults that positional stage args override
    assert make_compressor("topk", fraction=0.03).fraction == 0.03
    assert make_compressor("topk:0.5", fraction=0.03).fraction == 0.5
    assert make_compressor(None).is_identity
    assert make_compressor("none").is_identity
    assert make_compressor("none>>qsgd:8").name == "qsgd8"
    with pytest.raises(KeyError):
        make_compressor("nope:3")
    with pytest.raises(KeyError):
        make_compressor("topk:0.01>>nope")


def test_all_legacy_names_resolve():
    for name in ["none", "qsgd8", "qsgd4", "lfl8", "uveq", "hsq", "topk",
                 "stc", "sbc", "randmask", "sketch"]:
        comp = make_compressor(name, fraction=0.05, cols=256)
        y = comp.roundtrip(jax.random.PRNGKey(0), _x(0, 3000))
        assert bool(jnp.isfinite(y).all()), name


# ---------------------------------------------------------------------------
# wrapping transforms: state ownership
# ---------------------------------------------------------------------------

def test_error_feedback_state_threading():
    """encode() must consume and return the residual; over rounds the EF'd
    mean reconstruction approaches the mean input (bias correction)."""
    n = 2048
    pipe = error_feedback(make_compressor("topk", fraction=0.05))
    assert pipe.stateful and not pipe.biased
    st = pipe.init((n,))
    assert st["residual"].shape == (n,)
    x = _x(0, n)
    acc = jnp.zeros((n,))
    for t in range(40):
        payload, st = pipe.encode(st, jax.random.PRNGKey(t), x)
        acc = acc + pipe.decode(payload, n)
    mean = acc / 40
    rel = float(jnp.linalg.norm(mean - x) / jnp.linalg.norm(x))
    assert rel < 0.25, rel                      # plain top-k@5% leaves ~0.95
    # wire accounting is the inner pipeline's
    inner = make_compressor("topk", fraction=0.05)
    assert pipe.wire_bits(n) == inner.wire_bits(n)


def test_error_feedback_state_is_leaf_shaped():
    """Residuals must match the leaf shape they were init'd with (so they
    shard like the parameter)."""
    pipe = error_feedback(make_compressor("stc", fraction=0.1))
    st = pipe.init((8, 16))
    assert st["residual"].shape == (8, 16)
    x = _x(1, 128)
    payload, st2 = pipe.encode(st, jax.random.PRNGKey(0), x)
    assert st2["residual"].shape == (8, 16)


def test_momentum_correction_accumulates_unsent():
    """DGC: with a constant input every coordinate is eventually transmitted
    — the accumulated v forces small coordinates into the top-k."""
    n = 512
    pipe = momentum_correction(make_compressor("topk", fraction=0.05),
                               momentum=0.0)    # isolate the accumulation
    st = pipe.init((n,))
    x = jnp.abs(_x(0, n)) + 0.1                 # strictly positive
    sent = jnp.zeros((n,), bool)
    for t in range(120):
        payload, st = pipe.encode(st, jax.random.PRNGKey(t), x)
        sent = sent | (pipe.decode(payload, n) != 0)
    assert float(sent.mean()) > 0.95, float(sent.mean())


def test_pipeline_jit_roundtrip():
    """Chained encode/decode with state must jit cleanly (it runs inside the
    shard_map aggregation in deployment)."""
    n = 4096
    pipe = error_feedback(make_compressor("topk:0.05>>qsgd:8"))
    st = pipe.init((n,))

    @jax.jit
    def step(st, rng, x):
        payload, st = pipe.encode(st, rng, x)
        return pipe.decode(payload, n), st

    x = _x(0, n)
    y, st = step(st, jax.random.PRNGKey(0), x)
    y2, st = step(st, jax.random.PRNGKey(1), x)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(y2).all())
    assert float(jnp.abs(st["residual"]).sum()) > 0.0
