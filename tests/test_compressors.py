"""Property-based tests of the compression substrate.

Fuzzed properties use ``hypothesis`` when it is installed; without it each
fuzzed test degrades to a fixed-seed parametrized sweep so the core
round-trip/error-bound assertions still run (the CI image pins hypothesis,
minimal images may not have it).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401 — probe only; see `fuzz` below
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.compress import make_compressor
from repro.compress.sketch import sketch, unsketch

ALL = ["none", "qsgd8", "qsgd4", "uveq", "hsq", "topk", "stc", "sbc",
       "randmask", "sketch"]
UNBIASED = ["none", "qsgd8", "qsgd4", "uveq", "randmask"]


def fuzz(*strategies, fallback, max_examples=20):
    """``@given(*strategies)`` under hypothesis; fixed-example parametrize
    otherwise. ``fallback`` is a list of argument tuples."""
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=max_examples, deadline=None)(
                given(*strategies)(fn))
        nargs = fn.__code__.co_argcount
        argnames = ",".join(fn.__code__.co_varnames[:nargs])
        vals = [t[0] for t in fallback] if nargs == 1 else fallback
        return pytest.mark.parametrize(argnames, vals)(fn)
    return deco


def _st(builder):
    """Build a strategy lazily so module import never touches hypothesis."""
    return builder() if HAVE_HYPOTHESIS else None


def _x(seed, n, scale):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale


@pytest.mark.parametrize("name", ALL)
def test_roundtrip_shape_dtype(name):
    comp = make_compressor(name, fraction=0.05, cols=512)
    x = _x(0, 3000, 2.0)
    y = comp.roundtrip(jax.random.PRNGKey(1), x)
    assert y.shape == x.shape
    assert y.dtype == jnp.float32
    assert bool(jnp.isfinite(y).all())


@pytest.mark.parametrize("name", UNBIASED)
def test_unbiasedness(name):
    """E[Q(x)] = x for the stochastic quantizers / 1/p-rescaled masks."""
    comp = make_compressor(name, fraction=0.25, block=256)
    x = _x(2, 512, 1.0)
    reps = 300
    acc = jnp.zeros_like(x)
    for i in range(reps):
        acc = acc + comp.roundtrip(jax.random.PRNGKey(i), x)
    mean = acc / reps
    err = float(jnp.abs(mean - x).mean()) / float(jnp.abs(x).mean())
    assert err < 0.1, (name, err)


@pytest.mark.parametrize("name", ["topk", "stc", "sbc", "hsq"])
def test_biased_flagged_for_error_feedback(name):
    assert make_compressor(name).biased


@pytest.mark.parametrize("name", ALL)
def test_wire_bits_monotone_and_saving(name):
    comp = make_compressor(name, fraction=0.01, cols=512)
    assert comp.wire_bits(1 << 20) >= comp.wire_bits(1 << 10) or name == "sketch"
    if name not in ("none",):
        assert comp.wire_bits(1 << 20) < 32.0 * (1 << 20)  # beats dense f32
    assert comp.entropy_bits(1 << 20) <= comp.wire_bits(1 << 20) + 1e-6


def test_topk_keeps_largest():
    comp = make_compressor("topk", fraction=0.01)
    x = _x(3, 1000, 1.0).at[7].set(100.0)
    y = comp.roundtrip(jax.random.PRNGKey(0), x)
    assert float(y[7]) == 100.0


def test_stc_ternary_levels():
    comp = make_compressor("stc", fraction=0.1)
    x = _x(4, 1000, 3.0)
    y = np.asarray(comp.roundtrip(jax.random.PRNGKey(0), x))
    vals = np.unique(np.abs(y[y != 0]))
    assert len(vals) == 1          # single magnitude mu
    assert int((y != 0).sum()) >= 100


def test_sbc_single_sign():
    comp = make_compressor("sbc", fraction=0.1)
    x = _x(5, 1000, 1.0)
    y = np.asarray(comp.roundtrip(jax.random.PRNGKey(0), x))
    nz = y[y != 0]
    assert len(np.unique(nz)) == 1  # one signed magnitude only


@fuzz(_st(lambda: st.integers(0, 2**31 - 1)), _st(lambda: st.floats(0.1, 10.0)),
      fallback=[(0, 0.1), (1, 1.0), (7, 3.3), (123, 10.0), (999, 0.5)])
def test_qsgd_error_bounded_by_block_scale(seed, scale):
    """|x - Q(x)| <= scale_block / levels per coordinate (QSGD guarantee)."""
    comp = make_compressor("qsgd8", block=128)
    x = _x(seed % 1000, 512, scale)
    y = comp.roundtrip(jax.random.PRNGKey(seed % 997), x)
    xb = np.asarray(x).reshape(4, 128)
    errb = np.asarray(y - x).reshape(4, 128)
    for b in range(4):
        bound = np.abs(xb[b]).max() / 127 + 1e-6
        assert np.abs(errb[b]).max() <= bound + 1e-5


@fuzz(_st(lambda: st.integers(0, 10_000)),
      fallback=[(0,), (17,), (512,), (4095,), (9999,)], max_examples=15)
def test_sketch_linearity(seed):
    """sketch(a + b) == sketch(a) + sketch(b) — what lets FetchSGD aggregate
    sketches server-side."""
    a = _x(seed, 2048, 1.0)
    b = _x(seed + 1, 2048, 2.0)
    Sa = sketch(a, 5, 256)
    Sb = sketch(b, 5, 256)
    Sab = sketch(a + b, 5, 256)
    np.testing.assert_allclose(np.asarray(Sa + Sb), np.asarray(Sab),
                               rtol=1e-4, atol=1e-4)


def test_error_feedback_contraction():
    """The EF residual of top-k stays bounded: ||e_t|| <= (1-k/n)·growth."""
    comp = make_compressor("topk", fraction=0.1)
    n = 1000
    e = jnp.zeros((n,))
    norms = []
    for t in range(30):
        g = _x(t, n, 1.0)
        target = g + e
        q = comp.roundtrip(jax.random.PRNGKey(t), target)
        e = target - q
        norms.append(float(jnp.linalg.norm(e)))
    # residual norm must stabilise (contraction), not blow up
    assert max(norms[10:]) < 3.0 * np.mean(norms[:5])


@fuzz(_st(lambda: st.integers(2, 64)),
      fallback=[(2,), (5,), (13,), (40,), (64,)], max_examples=10)
def test_randmask_deterministic_given_seed(k):
    comp = make_compressor("randmask", fraction=0.2)
    x = _x(k, 256, 1.0)
    p1 = comp.compress(jax.random.PRNGKey(k), x)
    y1 = comp.decompress(p1, 256)
    y2 = comp.decompress(p1, 256)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
