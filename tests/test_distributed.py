"""Drives the multi-device integration cases in subprocesses (each needs
``xla_force_host_platform_device_count`` set before jax import, which must
not leak into this pytest process — the dry-run owns 512, we use 8 here)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

CASES = [
    "fedsgd_equals_centralized",
    "all_algorithms_converge",
    "ledger_accounting_exact",
    "selection_counts",
    "hier_and_gossip",
    "ef_residual_on_edge_hop",
    "kernel_backend_edge_hop",
    "pipeline_chain_agg",
    "noniid_data_pipeline",
    "compressed_agg_collectives_in_hlo",
    "population_star_bitexact",
    "secagg_masked_bitexact",
    "telemetry_bitexact",
]


@pytest.mark.parametrize("case", CASES)
def test_distributed_case(case):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run(
        [sys.executable, os.path.join(HERE, "distributed_cases.py"), case],
        capture_output=True, text=True, env=env, timeout=900)
    assert p.returncode == 0, f"\n--- stdout ---\n{p.stdout}\n--- stderr ---\n{p.stderr[-3000:]}"
    assert f"PASS {case}" in p.stdout
