"""Scenario conformance harness (core.scenario, DESIGN.md §13).

The headline contract, in the style of tests/test_obs.py: client-dynamics
scenarios are **masks over today's engines**, never new engines.  Two
anchors prove it differentially:

  * **OFF is the identity graph** — a default FLConfig builds engines with
    no scenario hop and no extra async_state keys (structural assert), so
    the scenario-free path is literally unchanged code.
  * **Degenerate-ON is bit-exact** — ``parity_cases.SCENARIO_CASES`` runs
    enabled-but-identity scenarios (duty-1.0 traces, epoch-scale floor
    1.0) through sim / population / async engines over kernel, fused, and
    secagg wire specs: params, comm_state, and ledger bytes must match the
    scenario-free run bit-for-bit, proving the dynamics enter ONLY through
    the masks they draw.

Around the anchors: trace duty-cycle and dropout-shape properties
(hypothesis when installed, fixed-seed sweep otherwise), the adaptive
deadline's quantile-tracker convergence, the availability seam regression
(population and dense selection share ONE mask implementation), secagg
safety of dropout zero-weighting, and the ResidualStore eviction-under-
churn property (scenario-driven cohort membership never corrupts LRU
stamps; store counters reconcile with the scenario's masks).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401 — probe only; see `fuzz` below
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from parity_cases import SCENARIO_CASES
from repro.compress.residual_store import ResidualStore
from repro.configs.registry import get_arch
from repro.core import scenario as scn
from repro.core.engine import (Topology, make_round_engine, run_rounds,
                               uplink_pipeline)
from repro.core.population import ClientPopulation
from repro.core.types import FLConfig
from repro.data.pipeline import capability_latency, cohort_data_fn
from repro.data.synthetic import FedDataConfig, sample_round


def fuzz(*strategies, fallback, max_examples=10):
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=max_examples, deadline=None)(
                given(*strategies)(fn))
        nargs = fn.__code__.co_argcount
        argnames = ",".join(fn.__code__.co_varnames[:nargs])
        vals = [t[0] for t in fallback] if nargs == 1 else fallback
        return pytest.mark.parametrize(argnames, vals)(fn)
    return deco


def _st(builder):
    return builder() if HAVE_HYPOTHESIS else None


CFG = get_arch("paper_lm")
DATA = FedDataConfig(vocab_size=CFG.vocab_size, num_clients=4, seq_len=32,
                     batch_per_client=2, heterogeneity=1.5)


def _data_fn(r):
    return sample_round(DATA, jax.random.fold_in(jax.random.PRNGKey(1), r))


def _run(spec, topo_fn, pop=None, n=3, data_fn=None, **fl_kw):
    from repro.models.model import Model
    model = Model(CFG)
    fl_kw.setdefault("local_steps", 2)
    fl = FLConfig(algorithm="fedavg", local_lr=0.2,
                  uplink_compressor=spec, **fl_kw)
    dfn = data_fn or _data_fn
    e = make_round_engine(model, fl, topo_fn(), chunk=32, data_fn=dfn,
                          population=pop)
    state = e.init_fn(jax.random.PRNGKey(0))
    state, ms = run_rounds(e, state, dfn, n, chunk=1, donate=False)
    return e, state, ms


def _assert_leaves_equal(what, a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), f"{what}: leaf count diverged"
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y),
                              equal_nan=True), f"{what} diverged"


# ---------------------------------------------------------------------------
# structural OFF: a default config builds today's exact engines
# ---------------------------------------------------------------------------

def test_default_config_has_no_scenario():
    assert not scn.Scenario.from_fl(FLConfig()).enabled
    # every identity knob individually keeps the scenario disabled
    assert not scn.Scenario(trace="static", availability=1.0, dropout=0.0,
                            epoch_scale=0.0, deadline_quantile=0.0).enabled
    assert scn.Scenario(trace="square").enabled
    assert scn.Scenario(availability=0.5).enabled
    assert scn.Scenario(dropout=0.1).enabled
    assert scn.Scenario(epoch_scale=0.5).enabled
    assert scn.Scenario(deadline_quantile=0.9).enabled


def test_off_graph_has_no_scenario_hops():
    e, state, _ = _run("topk:0.25>>qsgd:8", lambda: Topology.sim(4), n=1)
    names = [name for name, _ in e.program.hops]
    assert "scenario_dropout" not in names
    # the dispatch body carries no epoch-steps branch when disabled
    assert all(k not in (state.async_state or {})
               for k in ("q_est", "slot_lat"))


def test_off_async_state_has_no_scenario_keys():
    e, state, _ = _run("topk:0.25>>qsgd:8",
                       lambda: Topology.async_(4, buffer_size=2), n=1)
    assert "q_est" not in state.async_state
    assert "slot_lat" not in state.async_state


def test_scenario_validation():
    with pytest.raises(ValueError):
        scn.Scenario(trace="lunar")
    with pytest.raises(ValueError):
        scn.Scenario(availability=0.0)
    with pytest.raises(ValueError):
        scn.Scenario(dropout=-1.0)
    with pytest.raises(ValueError):
        scn.Scenario(epoch_scale=1.5)
    with pytest.raises(ValueError):
        scn.Scenario(deadline_quantile=1.0)


def test_hier_and_gossip_reject_scenarios():
    from repro.models.model import Model
    model = Model(CFG)
    fl = FLConfig(scenario_dropout=0.5)
    for topo in (Topology.hier(4), Topology.gossip()):
        with pytest.raises(ValueError, match="scenario"):
            make_round_engine(model, fl, topo, chunk=32, data_fn=_data_fn)


def test_async_rejects_availability_traces():
    from repro.models.model import Model
    model = Model(CFG)
    for kw in (dict(scenario_trace="square"),
               dict(scenario_availability=0.5)):
        with pytest.raises(ValueError, match="completion order"):
            make_round_engine(Model(CFG), FLConfig(**kw),
                              Topology.async_(4), chunk=32,
                              data_fn=_data_fn)


def test_epoch_scale_needs_multi_step_scannable_algorithm():
    from repro.models.model import Model
    with pytest.raises(ValueError, match="local_steps"):
        make_round_engine(Model(CFG),
                          FLConfig(scenario_epoch_scale=0.5, local_steps=1),
                          Topology.sim(4), chunk=32, data_fn=_data_fn)
    with pytest.raises(ValueError, match="scaffold"):
        make_round_engine(Model(CFG),
                          FLConfig(scenario_epoch_scale=0.5, local_steps=2,
                                   algorithm="scaffold"),
                          Topology.sim(4), chunk=32, data_fn=_data_fn)


# ---------------------------------------------------------------------------
# the differential anchor: degenerate-ON scenarios are bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c", SCENARIO_CASES, ids=lambda c: c["name"])
def test_degenerate_scenario_bitexact_sim(c):
    off = _run(c["spec"], lambda: Topology.sim(4))
    on = _run(c["spec"], lambda: Topology.sim(4), **c["fl"])
    _assert_leaves_equal(f"sim/{c['name']} params", off[1].params,
                         on[1].params)
    _assert_leaves_equal(f"sim/{c['name']} comm_state", off[1].comm_state,
                         on[1].comm_state)
    _assert_leaves_equal(f"sim/{c['name']} ledger", off[2]["ledger"],
                         on[2]["ledger"])


@pytest.mark.parametrize("avail,fl_kw", [
    # duty-1.0 square trace: the mask hop runs and emits all-ones
    (1.0, dict(scenario_trace="square")),
    # epoch-scale floor 1.0 under a genuinely sub-1.0 Bernoulli rate
    (0.8, dict(scenario_epoch_scale=1.0)),
], ids=["square_duty1", "escale_floor1"])
def test_degenerate_scenario_bitexact_population(avail, fl_kw):
    def make():
        return ClientPopulation(n_clients=16, cohort=8, availability=avail,
                                seed=3)
    data = FedDataConfig(vocab_size=CFG.vocab_size, num_clients=16,
                         seq_len=32, batch_per_client=2, heterogeneity=1.5)
    off = _run("topk:0.25>>qsgd:8", lambda: Topology.sim(16), pop=make(),
               data_fn=cohort_data_fn(make(), data))
    on = _run("topk:0.25>>qsgd:8", lambda: Topology.sim(16), pop=make(),
              data_fn=cohort_data_fn(make(), data), **fl_kw)
    _assert_leaves_equal("pop params", off[1].params, on[1].params)
    _assert_leaves_equal("pop comm_state", off[1].comm_state,
                         on[1].comm_state)
    _assert_leaves_equal("pop ledger", off[2]["ledger"], on[2]["ledger"])


def test_degenerate_scenario_bitexact_async():
    topo = lambda: Topology.async_(4, buffer_size=2,
                                   latency_profile="heavy_tail")
    off = _run("topk:0.25>>qsgd:8", topo, n=6)
    on = _run("topk:0.25>>qsgd:8", topo, n=6, scenario_epoch_scale=1.0)
    _assert_leaves_equal("async params", off[1].params, on[1].params)
    _assert_leaves_equal("async comm_state", off[1].comm_state,
                         on[1].comm_state)
    _assert_leaves_equal("async ledger", off[2]["ledger"], on[2]["ledger"])


# ---------------------------------------------------------------------------
# availability seam (one shared mask implementation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,rate", [(0, 0.5), (3, 0.8), (11, 0.3)])
def test_population_and_scenario_share_the_bernoulli_draw(seed, rate):
    """Regression for the selection/population seam: identical (seed,
    round) must yield identical masks whether drawn through the population
    or directly from core.scenario — they are the same function now."""
    pop = ClientPopulation(n_clients=32, cohort=32, availability=rate,
                           seed=seed)
    ids = jnp.arange(32, dtype=jnp.int32)
    for r in (0, 1, 7, 100):
        r = jnp.int32(r)
        via_pop = pop.availability_mask(r, ids)
        direct = scn.bernoulli_mask(seed, rate, r, ids)
        shared = scn.availability_mask(None, seed, rate, r, ids)
        assert np.array_equal(np.asarray(via_pop), np.asarray(direct))
        assert np.array_equal(np.asarray(via_pop), np.asarray(shared))


def test_population_scenario_trace_delegates():
    s = scn.Scenario(trace="square", period=8.0)
    import dataclasses
    pop = dataclasses.replace(
        ClientPopulation(n_clients=16, cohort=16, availability=0.5, seed=2),
        scenario=s)
    ids = jnp.arange(16, dtype=jnp.int32)
    for r in (0, 3, 9):
        r = jnp.int32(r)
        via_pop = pop.availability_mask(r, ids)
        direct = scn.availability_mask(s, 2, 0.5, r, ids)
        assert np.array_equal(np.asarray(via_pop), np.asarray(direct))


# ---------------------------------------------------------------------------
# trace properties: duty cycles
# ---------------------------------------------------------------------------

@fuzz(_st(lambda: st.floats(0.1, 0.9)), _st(lambda: st.integers(0, 99)),
      fallback=[(0.25, 0), (0.5, 7), (0.75, 42)], max_examples=6)
def test_square_trace_hits_exact_duty_cycle(rate, seed):
    """Over full periods, every client's square-trace duty cycle equals
    the configured rate up to the 1/period quantization of the window."""
    period = 8.0
    s = scn.Scenario(trace="square", period=period, availability=rate,
                     seed=seed)
    ids = jnp.arange(16, dtype=jnp.int32)
    rounds = int(period) * 10
    masks = np.stack([
        np.asarray(scn.availability_mask(s, seed, rate, jnp.int32(r), ids))
        for r in range(rounds)])
    duty = masks.mean(axis=0)                       # per-client
    assert np.all(np.abs(duty - rate) <= 1.0 / period + 1e-6), duty


@fuzz(_st(lambda: st.floats(0.2, 0.8)), _st(lambda: st.integers(0, 99)),
      fallback=[(0.3, 1), (0.5, 5), (0.7, 23)], max_examples=4)
def test_diurnal_trace_hits_mean_duty_cycle(rate, seed):
    """The sinusoid's amplitude clamp keeps the diurnal trace's
    time-average duty at the configured rate (population mean over clients
    x rounds; 5-sigma Bernoulli tolerance)."""
    period = 8.0
    s = scn.Scenario(trace="diurnal", period=period, availability=rate,
                     seed=seed)
    ids = jnp.arange(32, dtype=jnp.int32)
    rounds = int(period) * 8
    masks = np.stack([
        np.asarray(scn.availability_mask(s, seed, rate, jnp.int32(r), ids))
        for r in range(rounds)])
    n = masks.size
    sigma = np.sqrt(rate * (1 - rate) / n)
    assert abs(masks.mean() - rate) < 5 * sigma + 1.0 / n, masks.mean()


def test_diurnal_rate_modulates_with_phase():
    """The trace is genuinely time-varying PER CLIENT: availability draws
    binned by each client's position in its own period show the sinusoid
    (population means hide it — random phases decorrelate the clients)."""
    period, rate = 8.0, 0.5
    s = scn.Scenario(trace="diurnal", period=period, availability=rate,
                     seed=0)
    ids = jnp.arange(256, dtype=jnp.int32)
    phi = np.asarray(scn.client_phases(0, ids))
    peak, trough = [], []
    for r in range(64):
        frac = np.mod(r / period + phi, 1.0)
        sine = np.sin(2 * np.pi * frac)
        m = np.asarray(scn.availability_mask(s, 0, rate, jnp.int32(r), ids))
        peak.extend(m[sine > 0.9].tolist())
        trough.extend(m[sine < -0.9].tolist())
    # p = 0.5 + 0.5*sin: near-certain at the peak, near-zero at the trough
    assert np.mean(peak) > 0.85, np.mean(peak)
    assert np.mean(trough) < 0.15, np.mean(trough)


# ---------------------------------------------------------------------------
# dropout properties
# ---------------------------------------------------------------------------

def test_dropout_never_changes_payload_shapes_or_bytes():
    """Partial-update semantics: dropout zero-weights rows, it never
    reshapes the wire — ledger bytes are identical to the dropout-free
    run, round for round."""
    off = _run("topk:0.25>>qsgd:8", lambda: Topology.sim(4), n=4)
    on = _run("topk:0.25>>qsgd:8", lambda: Topology.sim(4), n=4,
              scenario_dropout=1.0)
    _assert_leaves_equal("dropout ledger bytes", off[2]["ledger"],
                         on[2]["ledger"])
    # ... but it does change the trajectory (the hazard is huge)
    la = jax.tree.leaves(off[1].params)
    lb = jax.tree.leaves(on[1].params)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def test_dropout_secagg_masked_matches_clear():
    """Secagg safety: pairwise masks cancel identically whether or not
    the aggregation zero-weights dropped clients — the masked wire with
    dropout reproduces the clear wire with dropout bit-exactly."""
    kw = dict(scenario_dropout=0.5)
    clear = _run("qsgd:4", lambda: Topology.sim(4), n=3, **kw)
    masked = _run("qsgd:4>>secagg", lambda: Topology.sim(4), n=3, **kw)
    _assert_leaves_equal("secagg+dropout params", clear[1].params,
                         masked[1].params)


@fuzz(_st(lambda: st.floats(0.0, 3.0)), fallback=[(0.0,), (0.5,), (2.0,)],
      max_examples=6)
def test_survival_prob_monotone_in_latency(hazard):
    s = scn.Scenario(dropout=hazard) if hazard > 0 else scn.Scenario()
    if hazard == 0.0:
        return
    lat = jnp.asarray([0.1, 1.0, 10.0], jnp.float32)
    p = np.asarray(scn.survival_prob(s, lat))
    assert np.all(np.diff(p) <= 1e-7)             # slower => dies more
    assert np.all((p >= 0.0) & (p <= 1.0))


def test_scenario_telemetry_counters():
    e, state, ms = _run("topk:0.25>>qsgd:8", lambda: Topology.sim(4), n=4,
                        telemetry=True, scenario_trace="diurnal",
                        scenario_availability=0.6, scenario_dropout=0.5)
    rs = ms["round_stats"]
    duty = np.asarray(rs.avail_duty)
    dropped = np.asarray(rs.dropped)
    assert np.all((duty >= 0.0) & (duty <= 1.0))
    # dropped counts previously-selected clients that died mid-round, and
    # selected counts the survivors — together they cannot exceed the
    # client axis
    assert np.all(dropped + np.asarray(rs.selected) <= 4.0 + 1e-6)
    assert np.all(dropped >= 0.0)
    assert np.all(np.asarray(rs.available) == duty * 4.0)


def test_epoch_scale_histogram_populated():
    e, state, ms = _run("topk:0.25>>qsgd:8", lambda: Topology.sim(4), n=2,
                        telemetry=True, scenario_epoch_scale=0.25)
    h = np.asarray(ms["round_stats"].epoch_scale_hist)
    assert h.shape[-1] == 8
    assert np.all(h.sum(axis=-1) == 4.0)          # one bucket per client


# ---------------------------------------------------------------------------
# heterogeneity-aware dispatch
# ---------------------------------------------------------------------------

def test_epoch_steps_budgets():
    res = np.ones((8, 4), np.float32)
    res[:, 0] = np.linspace(0.1, 2.0, 8)          # cpu spread
    s = scn.Scenario(epoch_scale=0.25)
    n, scale = scn.epoch_steps(s, 8, jnp.asarray(res))
    n, scale = np.asarray(n), np.asarray(scale)
    assert np.all((n >= 1) & (n <= 8))
    assert np.all((scale >= 0.25) & (scale <= 1.0))
    lat = np.asarray(capability_latency(jnp.asarray(res)))
    # slowest client gets the floor; the median device runs full budget
    assert scale[np.argmax(lat)] == 0.25
    assert n[np.argsort(lat)[3]] == 8 or n[np.argsort(lat)[4]] == 8


def test_epoch_scale_changes_trajectory_but_not_shapes():
    off = _run("topk:0.25>>qsgd:8", lambda: Topology.sim(4), n=3)
    on = _run("topk:0.25>>qsgd:8", lambda: Topology.sim(4), n=3,
              scenario_epoch_scale=0.25)
    _assert_leaves_equal("escale ledger", off[2]["ledger"], on[2]["ledger"])
    la, lb = jax.tree.leaves(off[1].params), jax.tree.leaves(on[1].params)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# adaptive deadline: quantile tracker
# ---------------------------------------------------------------------------

@fuzz(_st(lambda: st.floats(0.2, 0.95)), _st(lambda: st.integers(0, 999)),
      fallback=[(0.5, 0), (0.9, 7), (0.25, 99)], max_examples=6)
def test_quantile_update_converges_on_uniform(quantile, seed):
    """Robbins-Monro convergence: on U[1, 2] samples the tracker settles
    near the true quantile ``1 + quantile`` (oscillation ~ eta * q)."""
    rng = np.random.RandomState(seed)
    q = jnp.float32(1.5)
    for _ in range(3000):
        q = scn.quantile_update(q, jnp.float32(rng.uniform(1.0, 2.0)),
                                quantile)
    true_q = 1.0 + quantile
    assert abs(float(q) - true_q) < 0.25, (float(q), true_q)


def test_async_adaptive_deadline_tracks_completion_quantile():
    e, state, ms = _run("topk:0.25>>qsgd:8",
                        lambda: Topology.async_(
                            4, buffer_size=4, latency_profile="constant"),
                        n=24, scenario_deadline_quantile=0.5)
    q = np.asarray(ms["q_est"])
    # constant profile: every completion takes 1.0 virtual seconds — the
    # estimate must stay in a tight band around it
    assert abs(q[-1] - 1.0) < 0.5, q[-1]
    assert "q_est" in state.async_state
    assert float(state.async_state["next_deadline"]) < np.inf


def test_async_dropout_zero_weights_arrivals():
    topo = lambda: Topology.async_(4, buffer_size=2,
                                   latency_profile="heavy_tail")
    off = _run("topk:0.25>>qsgd:8", topo, n=8)
    on = _run("topk:0.25>>qsgd:8", topo, n=8, scenario_dropout=0.5)
    # shapes and ledger identical (payloads still arrive, zero-weighted)
    _assert_leaves_equal("async dropout ledger", off[2]["ledger"],
                         on[2]["ledger"])
    la, lb = jax.tree.leaves(off[1].params), jax.tree.leaves(on[1].params)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# ResidualStore eviction under scenario churn (satellite: store-vs-scenario)
# ---------------------------------------------------------------------------

def _churn_store(eviction, seed, rounds=24):
    """Drive a small store with scenario-masked cohorts; return per-round
    (ids, stats) plus the store for invariants."""
    params = {"w": jnp.zeros((64,), jnp.float32)}
    pipe = uplink_pipeline(FLConfig(uplink_compressor="topk:0.5>>qsgd:8"))
    store = ResidualStore(pipe, params, capacity=8, eviction=eviction)
    state = store.init()
    s = scn.Scenario(trace="square", period=6.0, availability=0.5,
                     seed=seed)
    ids_all = jnp.arange(16, dtype=jnp.int32)
    log = []
    for r in range(rounds):
        mask = np.asarray(scn.availability_mask(s, seed, 0.5, jnp.int32(r),
                                                ids_all))
        # fixed-shape cohort: the 4 available (or lowest-id) clients
        order = np.lexsort((np.arange(16), -mask))
        ids = jnp.asarray(np.sort(order[:4]).astype(np.int32))
        stats = {k: float(v) for k, v in store.stats(state, ids).items()}
        rows, state = store.gather(state, ids)
        rows = jax.tree.map(lambda x: x + 1.0, rows)
        state = store.scatter(state, ids, rows)
        log.append((np.asarray(ids), stats))
    return store, state, log


@pytest.mark.parametrize("eviction", ["drop", "sketch"])
def test_store_eviction_under_scenario_churn(eviction):
    """Scenario-driven cohort membership never corrupts the LRU slab:
    counters reconcile every round, clients re-participating immediately
    always hit (capacity = 2 x cohort keeps the last two cohorts
    resident), and the resident set tracks the most recent scatters."""
    store, state, log = _churn_store(eviction, seed=5)
    prev = None
    for ids, stats in log:
        assert stats["hits"] + stats["misses"] == 4.0, stats
        assert stats["evictions"] <= stats["misses"]
        if eviction == "sketch":
            assert stats["sketch_recovered"] == stats["misses"]
        else:
            assert stats["sketch_recovered"] == 0.0
        if prev is not None:
            # back-to-back participants must be resident: the previous
            # round's scatter stamped them most-recent, and one round can
            # evict at most cohort(=4) of the 8 slots — the LRU ones
            repeat = len(set(ids.tolist()) & set(prev.tolist()))
            assert stats["hits"] >= repeat, (ids, prev, stats)
        prev = ids
    # final slab: every resident client id was scattered at some point
    resident = np.asarray(state["client"])
    seen = set()
    for ids, _ in log:
        seen.update(ids.tolist())
    assert set(resident[resident >= 0].tolist()) <= seen


def test_store_counters_reconcile_with_engine_scenario():
    """End-to-end: the telemetry store counters of a population run under
    a scenario reconcile — every round gathers exactly the cohort."""
    def make():
        return ClientPopulation(n_clients=16, cohort=4, capacity=8,
                                availability=0.7, seed=1)
    data = FedDataConfig(vocab_size=CFG.vocab_size, num_clients=16,
                         seq_len=32, batch_per_client=2, heterogeneity=1.5)
    e, state, ms = _run("topk:0.25>>qsgd:8", lambda: Topology.sim(16),
                        pop=make(), data_fn=cohort_data_fn(make(), data),
                        n=6, telemetry=True, scenario_trace="square",
                        scenario_dropout=0.3)
    rs = ms["round_stats"]
    hits = np.asarray(rs.store_hits)
    misses = np.asarray(rs.store_misses)
    assert np.all(hits + misses == 4.0)
    # selected counts post-dropout survivors, so the two partition the
    # pre-dropout selection: together they never exceed the cohort
    dropped = np.asarray(rs.dropped)
    selected = np.asarray(rs.selected)
    assert np.all(dropped >= 0.0) and np.all(dropped + selected <= 4.0)
    assert np.all(np.asarray(rs.avail_duty) <= 1.0)
