"""Model substrate: every family's forward/loss, and incremental decode ==
full forward (the KV-cache/SSM-state correctness contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import ArchConfig
from repro.models import model as MM
from repro.models.model import Model


def _cfgs():
    return {
        "dense": ArchConfig(name="d", family="dense", num_layers=2, d_model=64,
                            num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                            block_pattern=("attn+mlp",), dtype=jnp.float32,
                            remat=False, qkv_bias=True),
        "moe": ArchConfig(name="m", family="moe", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=97,
                          num_experts=4, experts_per_token=2,
                          expert_capacity_factor=8.0,
                          block_pattern=("attn+moe",), dtype=jnp.float32,
                          remat=False),
        "ssm": ArchConfig(name="s", family="ssm", num_layers=2, d_model=64,
                          num_heads=0, vocab_size=97, ssm_state=16,
                          ssm_head_dim=32, ssm_chunk=4,
                          block_pattern=("mamba",), dtype=jnp.float32,
                          remat=False),
        "hybrid": ArchConfig(name="h", family="hybrid", num_layers=4,
                             d_model=64, num_heads=4, num_kv_heads=2, d_ff=96,
                             vocab_size=97, num_experts=4, experts_per_token=2,
                             expert_capacity_factor=8.0, ssm_state=16,
                             ssm_head_dim=32, ssm_chunk=4,
                             block_pattern=("mamba+mlp", "attn+moe"),
                             dtype=jnp.float32, remat=False),
        "encdec": ArchConfig(name="e", family="encdec", num_layers=2,
                             d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                             vocab_size=97, encoder_layers=2,
                             frontend_tokens=8,
                             block_pattern=("attn+cross+mlp",),
                             dtype=jnp.float32, remat=False),
        "vlm": ArchConfig(name="v", family="vlm", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                          num_patches=8, block_pattern=("attn+mlp",),
                          dtype=jnp.float32, remat=False),
    }


def _batch(cfg, B=2, S=12, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks, "mask": jnp.ones((B, S))}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(7), (B, cfg.num_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(8), (B, cfg.frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("family", list(_cfgs()))
def test_loss_finite_and_grads_flow(family):
    cfg = _cfgs()[family]
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    (loss, metrics), g = jax.value_and_grad(
        lambda p_: m.loss(p_, batch, chunk=8), has_aux=True)(p)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid", "encdec"])
def test_decode_matches_forward(family):
    cfg = _cfgs()[family]
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    x, _ = MM.forward(p, batch, cfg, chunk=8)
    full_logits = MM.unembed(p, x, cfg)

    enc_len = cfg.frontend_tokens if cfg.family == "encdec" else 0
    cache = m.init_cache(B, S, enc_len=enc_len)
    if cfg.family == "encdec":
        from repro.models import layers as L
        enc_out = MM._encode(p, batch["frontend"].astype(jnp.float32), cfg)

        def fill(psb, csb):
            for i, e in enumerate(cfg.block_pattern):
                if "cross" in e.split("+"):
                    ek, ev = L.encode_cross_kv(psb[f"b{i}"]["cross"], enc_out,
                                               cfg)
                    csb[f"b{i}"]["enc"]["ek"] = ek
                    csb[f"b{i}"]["enc"]["ev"] = ev
            return csb
        cache = jax.vmap(fill)(p["layers"], cache)

    step = jax.jit(lambda p_, c, t, pos: m.decode(p_, c, t, pos))
    errs = []
    for t in range(S):
        logits, cache = step(p, cache, batch["tokens"][:, t:t + 1],
                             jnp.int32(t))
        errs.append(float(jnp.abs(logits[:, 0] - full_logits[:, t]).max()))
    assert max(errs) < 5e-3, (family, errs)


def test_sliding_window_ring_buffer_decode():
    cfg = _cfgs()["dense"]
    cfg = type(cfg)(**{**cfg.__dict__, "sliding_window": 4, "name": "w"})
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    x, _ = MM.forward(p, batch, cfg, chunk=8)
    full_logits = MM.unembed(p, x, cfg)
    cache = m.init_cache(B, 4)                    # ring buffer = window
    step = jax.jit(lambda p_, c, t, pos: m.decode(p_, c, t, pos, window=4))
    errs = []
    for t in range(S):
        logits, cache = step(p, cache, batch["tokens"][:, t:t + 1],
                             jnp.int32(t))
        errs.append(float(jnp.abs(logits[:, 0] - full_logits[:, t]).max()))
    assert max(errs) < 2e-3


def test_chunked_attention_equals_dense_reference():
    from repro.models.layers import chunked_attention
    B, S, H, KV, hd = 2, 24, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    pos = jnp.arange(S)
    for window, causal in [(0, True), (5, True), (0, False)]:
        out = chunked_attention(q, k, v, q_positions=pos, k_positions=pos,
                                causal=causal, window=window, chunk=7,
                                chunk_q=5)
        # dense reference
        kk = jnp.repeat(k, H // KV, axis=2)
        vv = jnp.repeat(v, H // KV, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk)
        mask = jnp.ones((S, S), bool)
        if causal:
            mask &= pos[None, :] <= pos[:, None]
        if window:
            mask &= pos[None, :] > pos[:, None] - window
        s = jnp.where(mask[None, None], s, -jnp.inf)
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1),
                         vv).reshape(B, S, H * hd)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)


def test_chunked_xent_matches_dense():
    from repro.models.model import chunked_xent
    B, S, D, V = 2, 16, 8, 31
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (D, V))
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    mask = (jax.random.uniform(jax.random.PRNGKey(3), (B, S)) > 0.3) \
        .astype(jnp.float32)
    tot, cnt = chunked_xent(x, w, labels, mask, chunk=4)
    logits = x @ w
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    ref = ((lse - gold) * mask).sum()
    np.testing.assert_allclose(float(tot), float(ref), rtol=1e-5)
    np.testing.assert_allclose(float(cnt), float(mask.sum()))


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size (the duality's contract)."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 2, 16, 3, 8, 5
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, 1, N))
    Cm = jax.random.normal(ks[4], (B, S, 1, N))
    D = jnp.ones((H,))
    y1 = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=2)
    y2 = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=8)
    y3 = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y3), rtol=2e-4,
                               atol=2e-5)


def test_ssd_equals_naive_recurrence():
    """Chunked SSD == step-by-step linear recurrence (the 'duality')."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 1, 12, 2, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, 1, N))
    Cm = jax.random.normal(ks[4], (B, S, 1, N))
    D = jnp.zeros((H,))
    y = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=4)

    h = np.zeros((B, H, N, P))
    outs = []
    for s in range(S):
        dA = np.exp(np.asarray(dt[:, s]) * np.asarray(A))       # (B,H)
        xb = np.einsum("bn,bhp->bhnp", np.asarray(Bm[:, s, 0]),
                       np.asarray(dt[:, s])[:, :, None] * np.asarray(x[:, s]))
        h = h * dA[:, :, None, None] + xb
        outs.append(np.einsum("bn,bhnp->bhp", np.asarray(Cm[:, s, 0]), h))
    ref = np.stack(outs, axis=1)                                 # (B,S,H,P)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-4)


def test_int8_kv_cache_decode_close_to_exact():
    """Quantized (int8 + per-token/head scale) KV cache — §Perf B2 — must
    track the exact decode closely."""
    cfg = _cfgs()["dense"]
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    x, _ = MM.forward(p, batch, cfg, chunk=8)
    full_logits = MM.unembed(p, x, cfg)
    cache = m.init_cache(B, S, quantized=True)
    assert cache["b0"]["kv"]["k"].dtype == jnp.int8
    step = jax.jit(lambda p_, c, t, pos: m.decode(p_, c, t, pos))
    errs = []
    for t in range(S):
        logits, cache = step(p, cache, batch["tokens"][:, t:t + 1],
                             jnp.int32(t))
        errs.append(float(jnp.abs(logits[:, 0] - full_logits[:, t]).max()))
    assert max(errs) < 0.05, errs
