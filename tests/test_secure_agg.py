"""Differential unmasking harness for the privacy wire stack (DESIGN.md §11).

The headline claim, in the style of tests/test_kernel_parity.py: a masked
run must equal the unmasked run **bit-exactly** — params, ctx-stripped
comm_state, and ledger wire bytes — because secagg's ring masks cancel in
integer arithmetic (mod 2^w), never in float arithmetic.  The harness runs
the masked-vs-base pairs of ``tests/parity_cases.PRIVACY_CASES`` at the
pipeline level and drives the sim / async / population engines end to end
(the star / hier / gossip wires run under 8 host devices in
tests/distributed_cases.case_secagg_masked_bitexact).

Dropout-of-one semantics are the mask-RECOVERY flavour: in-engine a dropped
(zero-weight) client can never corrupt the aggregate — decode unmasks per
client via the payload ctx — and at the raw code-plane level the tests show
the sum breaks without the dropped client's mask and is restored exactly by
``dropout_correction`` (the seed-recovery round of Bonawitz et al.).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_compressors import HAVE_HYPOTHESIS, _st, fuzz
if HAVE_HYPOTHESIS:
    from hypothesis import strategies as st

from parity_cases import PRIVACY_CASES, build
from repro.compress import make_compressor
from repro.compress.pipeline import error_feedback
from repro.compress.secure_agg import (CTX_BITS, DPNoise, SecAgg,
                                       bind_n_leaves, drop_mask_ctx,
                                       dropout_correction, has_mask_ctx,
                                       inject_mask_ctx, ring_mask,
                                       zcdp_epsilon)
from repro.compress.wire_format import payload_nbytes


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def _int_planes(payload):
    return [np.asarray(l) for l in jax.tree.leaves(payload)
            if np.issubdtype(np.asarray(l).dtype, np.integer)]


# ---------------------------------------------------------------------------
# Pipeline-level differential over PRIVACY_CASES
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c", PRIVACY_CASES, ids=lambda c: c["name"])
def test_masked_decode_bitexact(c):
    """With an injected cohort context, every client's masked payload
    decodes to exactly what the clear pipeline decodes — mask removal is
    integer subtraction, so there is no tolerance to grant."""
    masked, base = build(c, "jax"), _build_base(c)
    C, n = 4, 3001
    key = jax.random.PRNGKey(7)
    for i in range(C):
        r = jax.random.fold_in(jax.random.PRNGKey(1), i)
        x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(2), i),
                              (n,)) * 2.0
        pb, _ = base.encode(base.init((n,)), r, x)
        stm = inject_mask_ctx(masked.init((n,)), key, i, C)
        pm, _ = masked.encode(stm, r, x)
        assert np.array_equal(np.asarray(base.decode(pb, n)),
                              np.asarray(masked.decode(pm, n))), c["name"]


def _build_base(c):
    from repro.compress import make_compressor
    pipe = make_compressor(c["base"], backend="jax", **c["kw"])
    if c["wrapper"] == "ef":
        pipe = error_feedback(pipe)
    return pipe


@pytest.mark.parametrize("c", PRIVACY_CASES, ids=lambda c: c["name"])
def test_code_plane_sum_cancels(c):
    """Sum of masked integer planes over the full cohort == sum of clear
    planes mod 2^w — the secure-aggregation property itself, measured on
    the raw wire payloads (what a server summing masked codes would see)."""
    masked, base = build(c, "jax"), _build_base(c)
    C, n = 5, 3001
    key = jax.random.PRNGKey(3)
    clear_planes, masked_planes = None, None
    for i in range(C):
        r = jax.random.fold_in(jax.random.PRNGKey(1), i)
        x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(2), i),
                              (n,)) * 2.0
        pb, _ = base.encode(base.init((n,)), r, x)
        pm, _ = masked.encode(
            inject_mask_ctx(masked.init((n,)), key, i, C), r, x)
        pm = {k: v for k, v in pm.items() if k != "secagg_ctx"}
        cb = [p.astype(np.int64) for p in _int_planes(pb)]
        cm = [p.astype(np.int64) for p in _int_planes(pm)]
        clear_planes = cb if clear_planes is None else \
            [a + b for a, b in zip(clear_planes, cb)]
        masked_planes = cm if masked_planes is None else \
            [a + b for a, b in zip(masked_planes, cm)]
    for cp, mp, ref in zip(clear_planes, masked_planes, _int_planes(
            {k: v for k, v in pm.items()})):
        mod = 1 << (8 * ref.dtype.itemsize)
        assert np.array_equal(cp % mod, mp % mod), c["name"]


@pytest.mark.parametrize("c", PRIVACY_CASES, ids=lambda c: c["name"])
def test_ledger_and_payload_bytes(c):
    """Masking is free on the ledger (wire_bits identical to the clear
    pipeline) and costs exactly CTX_BITS/8 payload bytes per leaf (the
    simulated key-agreement channel); masked planes are uniform, so the
    entropy-coder estimate collapses to the wire bits."""
    masked, base = build(c, "jax"), _build_base(c)
    n = 5000
    assert masked.wire_bits(n) == base.wire_bits(n)
    assert payload_nbytes(masked, n) == payload_nbytes(base, n) + CTX_BITS // 8
    if c["wrapper"] is None and "dpnoise" not in c["spec"]:
        # SecAgg reports inner *wire* bits as its entropy estimate
        assert masked.entropy_bits(n) == masked.wire_bits(n)


def test_dropout_breaks_sum_and_correction_restores():
    """Dropout-of-one, made explicit: the partial masked sum is wrong by
    exactly the dropped client's mask, and dropout_correction recomputes it
    from the shared key (mask-recovery semantics)."""
    base = make_compressor("qsgd:4")
    masked = make_compressor("qsgd:4>>secagg")
    C, n, drop = 4, 3001, 2
    key = jax.random.PRNGKey(11)
    payloads, clears = [], []
    for i in range(C):
        r = jax.random.fold_in(jax.random.PRNGKey(1), i)
        x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(2), i),
                              (n,)) * 2.0
        pb, _ = base.encode(base.init((n,)), r, x)
        pm, _ = masked.encode(
            inject_mask_ctx(masked.init((n,)), key, i, C), r, x)
        payloads.append(pm)
        clears.append(pb)
    survivors = [i for i in range(C) if i != drop]
    qc = sum(np.asarray(clears[i]["q"], np.int64) for i in survivors) % 256
    qm = sum(np.asarray(payloads[i]["q"], np.int64) for i in survivors) % 256
    assert not np.array_equal(qc, qm), "dropout must break the masked sum"
    corr = dropout_correction(key, drop, C, clears[0])
    fixed = (qm + np.asarray(corr["q"], np.int64)) % 256
    assert np.array_equal(qc, fixed), "mask recovery must restore the sum"


def test_zero_weight_client_cannot_corrupt_engine_decode():
    """In-engine dropout safety: decode unmasks per client via the payload
    ctx, so the reconstruction of every OTHER client is untouched by who
    drops out — there is nothing weight-zeroing can corrupt."""
    masked = make_compressor("qsgd:4>>secagg")
    base = make_compressor("qsgd:4")
    n, C = 3001, 4
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    r = jax.random.PRNGKey(1)
    pm, _ = masked.encode(inject_mask_ctx(masked.init((n,)), key, 1, C), r, x)
    pb, _ = base.encode(base.init((n,)), r, x)
    assert np.array_equal(np.asarray(masked.decode(pm, n)),
                          np.asarray(base.decode(pb, n)))


# ---------------------------------------------------------------------------
# Guards (satellite: unmaskable combinations fail naming the carrier)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["secagg", "topk:0.05>>secagg",
                                  "sketch:3,512>>secagg",
                                  "randmask:0.1>>secagg"])
def test_secagg_rejects_float_carriers(spec):
    with pytest.raises(ValueError, match="quantizing carrier"):
        make_compressor(spec, fraction=0.05)


def test_secagg_error_names_a_fix():
    with pytest.raises(ValueError, match="qsgd:4>>secagg"):
        make_compressor("topk:0.05>>secagg")


def test_stage_after_privacy_rejected():
    with pytest.raises(ValueError, match="cannot follow a privacy stage"):
        make_compressor("qsgd:4>>secagg>>topk:0.1")


def test_nested_secagg_rejected():
    with pytest.raises(ValueError, match="once"):
        SecAgg(make_compressor("qsgd:4>>secagg"))


def test_privacy_suffix_rejected():
    with pytest.raises(ValueError, match="carrier stages"):
        make_compressor("qsgd:4>>secagg@kernel")


def test_dpnoise_needs_finite_clip_with_noise():
    with pytest.raises(ValueError, match="finite clip"):
        DPNoise(make_compressor("qsgd:4"), 0.5, float("inf"))


def test_dpnoise_accepts_colon_clip_form():
    # the ISSUE grammar "dpnoise:<sigma>[:<clip>]"; docs use the comma form
    a = make_compressor("qsgd:4>>dpnoise:0.8:2.0")
    b = make_compressor("qsgd:4>>dpnoise:0.8,2.0")
    assert a.name == b.name and a.clip == b.clip == 2.0


# ---------------------------------------------------------------------------
# Property tests (hypothesis-optional)
# ---------------------------------------------------------------------------

@fuzz(_st(lambda: st.integers(2, 9)), _st(lambda: st.integers(1, 257)),
      _st(lambda: st.sampled_from(["int8", "uint8", "int16", "int32"])),
      fallback=[(2, 17, "int8"), (5, 64, "uint8"), (3, 31, "int16"),
                (7, 257, "int32")], max_examples=12)
def test_ring_mask_cancellation_any_domain(C, n, dtype):
    """Sum of ring masks over any full cohort is identically zero in any
    integer code domain — the telescoping identity the whole stack rests
    on, independent of what pipeline produced the codes."""
    key = jax.random.PRNGKey(C * 1000 + n)
    ref = jnp.zeros((n,), jnp.dtype(dtype))
    total = np.zeros((n,), np.int64)
    for i in range(C):
        total += np.asarray(ring_mask(key, i, C, ref), np.int64)
    mod = 1 << (8 * np.dtype(dtype).itemsize)
    assert np.all(total % mod == 0)


@fuzz(_st(lambda: st.integers(0, 2 ** 16)),
      fallback=[(0,), (7,), (123,)], max_examples=8)
def test_dpnoise_sigma0_clipinf_is_noop(seed):
    """dpnoise(sigma=0, clip=inf) is a bit-exact no-op: payload, state and
    decode identical to the bare pipeline (the inner rng stream is passed
    through untouched)."""
    base = make_compressor("topk:0.05>>qsgd:4")
    noop = DPNoise(make_compressor("topk:0.05>>qsgd:4"), 0.0, float("inf"))
    n = 3001
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 2.0
    r = jax.random.fold_in(jax.random.PRNGKey(1), seed)
    pb, sb = base.encode(base.init((n,)), r, x)
    pn, sn = noop.encode(noop.init((n,)), r, x)
    assert _leaves_equal(pb, pn) and _leaves_equal(sb, sn)
    assert np.array_equal(np.asarray(base.decode(pb, n)),
                          np.asarray(noop.decode(pn, n)))
    assert noop.dp_rho_per_round() == 0.0


def test_dpnoise_rho_accounting():
    dp = make_compressor("topk:0.05>>qsgd:4>>dpnoise:0.8")
    assert dp.dp_rho_per_round() == pytest.approx(0.5 / 0.8 ** 2)
    both = make_compressor("qsgd:4>>dpnoise:0.5>>secagg")
    assert both.dp_rho_per_round() == pytest.approx(2.0)
    assert zcdp_epsilon(0.0) == 0.0
    assert zcdp_epsilon(2.0, 1e-5) > zcdp_epsilon(0.5, 1e-5) > 0.0


def test_dpnoise_multi_leaf_clip_splits_budget():
    """The billed rho = 0.5/sigma^2 is only correct if `clip` bounds the
    JOINT L2 of the whole update — with L leaves, each leaf must be
    clipped to clip/sqrt(L), not to the full clip (which would make the
    true cost L x 0.5/sigma^2 and the ledger a lie)."""
    n, clip, L = 257, 2.0, 4
    dp = DPNoise(make_compressor("none"), 0.0, clip)
    assert bind_n_leaves(dp, L) == 1
    # a leaf with norm above the per-leaf share gets scaled to clip/sqrt(L)
    x = jnp.ones((n,), jnp.float32)            # ||x|| = sqrt(257) > 1
    payload, _ = dp.encode(dp.init((n,)), jax.random.PRNGKey(0), x)
    nrm = float(jnp.linalg.norm(payload["x"]))
    assert nrm == pytest.approx(clip / math.sqrt(L), rel=1e-5)
    # joint sensitivity over L such leaves is back at `clip` exactly
    assert math.sqrt(L) * nrm == pytest.approx(clip, rel=1e-5)
    # rho stays leaf-count independent BECAUSE of the split
    noisy = DPNoise(make_compressor("none"), 0.5, clip)
    bind_n_leaves(noisy, L)
    assert noisy.dp_rho_per_round() == pytest.approx(0.5 / 0.5 ** 2)
    # a below-share leaf is untouched (clipping is a cap, not a rescale)
    small = jnp.full((n,), 1e-3, jnp.float32)
    p2, _ = dp.encode(dp.init((n,)), jax.random.PRNGKey(0), small)
    assert np.array_equal(np.asarray(p2["x"]), np.asarray(small))


def test_bind_n_leaves_walks_wrappers():
    """bind_n_leaves must reach a DPNoise nested under EF + SecAgg + Chain
    (the uplink_pipeline wrapping order) — and the engine's ledger_terms
    must bind the model's actual leaf count."""
    pipe = error_feedback(
        make_compressor("topk:0.05>>qsgd:4>>dpnoise:0.8>>secagg"))
    assert bind_n_leaves(pipe, 7) == 1
    inner = pipe.inner            # SecAgg
    assert inner.inner.n_leaves == 7
    assert bind_n_leaves(make_compressor("topk:0.05>>qsgd:4"), 3) == 0
    with pytest.raises(ValueError, match=">= 1"):
        bind_n_leaves(pipe, 0)

    from repro.configs.registry import get_arch
    from repro.core.engine import ledger_terms, _param_sizes
    from repro.core.types import FLConfig
    from repro.models.model import Model
    model = Model(get_arch("paper_lm"))
    _, up, _ = ledger_terms(model, FLConfig(
        uplink_compressor="topk:0.05>>qsgd:4>>dpnoise:0.8>>secagg"))
    L = len(_param_sizes(model))
    assert L > 1
    assert up.inner.inner.n_leaves == L      # ef -> secagg -> dpnoise


def test_uninjected_context_is_transparently_unmasked():
    """cohort=0 (the zero-initialised state) draws a zero mask, so the
    stage degrades to the clear pipeline outside an engine hop."""
    base = make_compressor("qsgd:4")
    masked = make_compressor("qsgd:4>>secagg")
    n = 3001
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    r = jax.random.PRNGKey(1)
    pb, _ = base.encode(base.init((n,)), r, x)
    pm, _ = masked.encode(masked.init((n,)), r, x)
    assert np.array_equal(np.asarray(pb["q"]), np.asarray(pm["q"]))


# ---------------------------------------------------------------------------
# Engine differentials: sim / async / population (star+hier+gossip run in
# tests/distributed_cases.case_secagg_masked_bitexact under 8 devices)
# ---------------------------------------------------------------------------

def _engine_pair(spec_base, spec_masked, topo_fn, pop=None, rounds=3,
                 **flkw):
    from repro.configs.registry import get_arch
    from repro.core.engine import make_round_engine, run_rounds
    from repro.core.types import FLConfig
    from repro.data.synthetic import FedDataConfig, sample_round
    from repro.models.model import Model

    cfg = get_arch("paper_lm")
    model = Model(cfg)
    fd = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=4, seq_len=32,
                       batch_per_client=2, heterogeneity=1.5)

    def dfn(r):
        return sample_round(fd, jax.random.fold_in(jax.random.PRNGKey(1), r))

    outs = []
    for spec in (spec_base, spec_masked):
        fl = FLConfig(uplink_compressor=spec, local_steps=1, local_lr=0.2,
                      latency_profile="constant", **flkw)
        e = make_round_engine(model, fl, topo_fn(), chunk=32, data_fn=dfn,
                              population=pop)
        st = e.init_fn(jax.random.PRNGKey(0))
        st, ms = run_rounds(e, st, dfn, rounds, chunk=2, donate=False)
        outs.append((st, ms))
    return outs


def _assert_engine_bitexact(tag, base_out, masked_out):
    (sb, mb), (sm, mm) = base_out, masked_out
    assert _leaves_equal(sb.params, sm.params), f"{tag}: params"
    cb = (sb.comm_state["slab"] if isinstance(sb.comm_state, dict)
          else sb.comm_state)
    cm = (sm.comm_state["slab"] if isinstance(sm.comm_state, dict)
          else sm.comm_state)
    cm = drop_mask_ctx(cm) if cm is not None else None
    assert _leaves_equal(cb if cb is not None else (),
                         cm if cm is not None else ()), f"{tag}: comm_state"
    # uplink_entropy intentionally differs: masked codes are uniform, so
    # the entropy-coder estimate collapses to the wire bits (DESIGN.md §11)
    for f in ("uplink_wire", "downlink_wire", "uplink_dense"):
        assert np.array_equal(np.asarray(getattr(mb["ledger"], f)),
                              np.asarray(getattr(mm["ledger"], f))), \
            f"{tag}: ledger.{f}"
    assert np.all(np.asarray(mm["ledger"].uplink_entropy)
                  >= np.asarray(mb["ledger"].uplink_entropy)), \
        f"{tag}: masked entropy below clear entropy"


@pytest.mark.parametrize("base,masked", [
    ("topk:0.05>>qsgd:4", "topk:0.05>>qsgd:4>>secagg"),   # EF chain
    ("qsgd:4@kernel", "qsgd:4@kernel>>secagg"),           # Pallas backend
    ("ternary@fused", "ternary@fused>>secagg"),           # packed wire
])
def test_sim_engine_masked_bitexact(base, masked):
    from repro.core.engine import Topology
    outs = _engine_pair(base, masked, lambda: Topology.sim(4))
    _assert_engine_bitexact(f"sim {masked}", outs[0], outs[1])


def test_async_engine_masked_bitexact():
    """Async arrival/flush path: pending rows are committed pre-decoded, so
    mask removal must already have happened per dispatch — bit-exactness
    across buffered aggregation proves the ctx threading survives it."""
    from repro.core.engine import Topology
    outs = _engine_pair(
        "topk:0.25>>qsgd:4", "topk:0.25>>qsgd:4>>secagg",
        lambda: Topology.async_(4, buffer_size=2,
                                latency_profile="constant"), rounds=6)
    _assert_engine_bitexact("async", outs[0], outs[1])


def test_population_engine_masked_bitexact():
    """ResidualStore gather/scatter: the mask ctx rows ride the slab like
    any comm state, and the degenerate population reproduces the dense
    masked run bit-for-bit."""
    from repro.core.engine import Topology
    from repro.core.population import ClientPopulation
    pop = ClientPopulation(n_clients=4, cohort=4, capacity=4)
    outs = _engine_pair("topk:0.25>>qsgd:4", "topk:0.25>>qsgd:4>>secagg",
                        lambda: Topology.sim(4), pop=pop)
    _assert_engine_bitexact("population", outs[0], outs[1])


def test_fl_config_knobs_match_spec_suffix():
    """FLConfig.secure_agg / dp_sigma / dp_clip produce the same pipeline
    as the spec-string suffixes (one grammar, two entry points)."""
    from repro.core.engine import uplink_pipeline
    from repro.core.types import FLConfig
    a = uplink_pipeline(FLConfig(uplink_compressor="qsgd:4",
                                 secure_agg=True))
    b = uplink_pipeline(FLConfig(uplink_compressor="qsgd:4>>secagg"))
    assert a.name == b.name
    c = uplink_pipeline(FLConfig(uplink_compressor="qsgd:4", dp_sigma=0.8,
                                 dp_clip=1.0, secure_agg=True))
    d = uplink_pipeline(FLConfig(
        uplink_compressor="qsgd:4>>dpnoise:0.8>>secagg"))
    assert c.name == d.name
    assert c.dp_rho_per_round() == pytest.approx(d.dp_rho_per_round())


def test_dp_rho_rides_the_ledger():
    """The privacy spend accumulates through the metrics ledger exactly
    like bytes: rounds x clients x rho per round."""
    from repro.core.engine import Topology
    outs = _engine_pair("topk:0.05>>qsgd:4",
                        "topk:0.05>>qsgd:4>>dpnoise:0.8>>secagg",
                        lambda: Topology.sim(4), rounds=3)
    _, (st, ms) = outs
    rho = np.asarray(ms["ledger"].dp_rho)
    per_round = 4 * 0.5 / 0.8 ** 2
    np.testing.assert_allclose(rho, per_round, rtol=1e-6)
    assert math.isfinite(zcdp_epsilon(rho.sum(), 1e-5))
    # the base run has no dpnoise stage -> no dp_rho leaf at all
    assert outs[0][1]["ledger"].dp_rho is None


def test_has_mask_ctx_walks_wrappers():
    ef = error_feedback(make_compressor("topk:0.05>>qsgd:4>>secagg"))
    assert has_mask_ctx(ef)
    assert not has_mask_ctx(make_compressor("topk:0.05>>qsgd:4"))
