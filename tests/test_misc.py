"""Sharding rules, checkpointing, ledger arithmetic, HLO analyzer."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.types import ArchConfig, CommLedger
from repro.models.sharding import spec_for
from repro.checkpoint import save, restore
from repro.launch.hlo_analysis import (_shape_bytes, _trip_count, analyze,
                                       parse_computations, roofline, dominant)


class FakeMesh:
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_spec_for_basic_tp():
    mesh = FakeMesh(data=16, model=16)
    # ffn weight: model on the hidden dim
    assert spec_for((512, 4096), ("embed", "ffn"), mesh, False) == \
        P(None, "model")
    # experts preferred over ffn
    assert spec_for((32, 512, 4096), ("experts", "embed", "ffn"),
                    mesh, False) == P("model", None, None)
    # fsdp 'extend' mode: widen the model dim when divisible by model*data...
    assert spec_for((512, 4096), ("embed", "ffn"), mesh, True) == \
        P(None, ("model", "data"))
    # ...else shard the rightmost eligible (output) dim — never contraction
    s = spec_for((32, 512, 4096), ("experts", "embed", "ffn"), mesh, True)
    assert s == P("model", None, "data")
    # non-divisible stays unsharded (50280 vocab)
    assert spec_for((50280, 1024), ("vocab", "embed"), mesh, False) == \
        P(None, "model")
    # norms never shard
    assert spec_for((1024,), ("norm",), mesh, False) == P(None)


def test_spec_never_reuses_axis():
    mesh = FakeMesh(data=4, model=4)
    s = spec_for((16, 16), ("ffn", "vocab"), mesh, True)
    flat = [a for a in s if a is not None]
    assert len(flat) == len(set(flat))


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save(path, tree)
        got = restore(path, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_roundtrip_population_store():
    """The ClientPopulation residual-store comm_state (slab + id map +
    sketch tail) is a plain dict pytree and must survive save/restore
    bit-for-bit — resuming a 1M-client run needs the slab contents AND
    the id->slot mapping intact (DESIGN.md §9)."""
    from repro.core.engine import uplink_pipeline
    from repro.core.population import ClientPopulation
    from repro.core.types import FLConfig

    pop = ClientPopulation(n_clients=1000, cohort=4, capacity=8,
                           eviction="sketch", tail_cols=256)
    pipe = uplink_pipeline(FLConfig(uplink_compressor="topk:0.25>>qsgd:8"))
    params = {"w": jnp.zeros((12,), jnp.float32)}
    store = pop.make_store(pipe, params)
    s = store.init()
    for r in range(3):          # populate slab, stamps, and the tail
        ids = pop.cohort_ids(r)
        rows, s = store.gather(s, ids)
        rows = jax.tree.map(lambda a: a + jnp.float32(r + 1), rows)
        s = store.scatter(s, ids, rows)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "store.npz")
        save(path, s)
        got = restore(path, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s))
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_selection_top_m_mask_exact_on_ties():
    """Regression (rank-based tie-break): ``scores >= thresh`` over-selected
    whole tie groups at the cut — the mask must have exactly m ones, with
    ties broken deterministically by ascending index."""
    import jax.numpy as jnp
    from repro.core import selection as sel
    from repro.core.types import FLConfig

    # all-equal scores: the old thresholding selected all C
    m = sel._top_m_mask(jnp.ones((10,)), 3)
    assert float(m.sum()) == 3.0
    assert np.asarray(m)[:3].all()            # lowest indices win ties
    # partial tie at the threshold
    m = sel._top_m_mask(jnp.array([1.0, 2.0, 2.0, 2.0, 0.5]), 2)
    np.testing.assert_array_equal(np.asarray(m), [0, 1, 1, 0, 0])
    # end-to-end: random selection draws can tie only pathologically, but
    # multi_criteria scores (resource means) tie easily — exactly m selected
    fl = FLConfig(selection="multi_criteria", clients_per_round=2)
    w = sel.select(fl, jax.random.PRNGKey(0),
                   losses=jnp.zeros((6,)),
                   resources=jnp.full((6, 4), 0.5),
                   sizes=jnp.ones((6,)))
    assert float((w > 0).sum()) == 2.0


def test_ledger_arithmetic():
    z = CommLedger.zero()
    l1 = CommLedger(*(jnp.float32(x) for x in (10, 8, 4, 100, 100)))
    tot = z + l1 + l1
    assert float(tot.uplink_wire) == 20
    assert float(l1.compression_ratio()) == pytest.approx(200 / 14.0)


HLO = """
HloModule test

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %x = f32[4] get-tuple-element(%p), index=1
  %ar = f32[4] all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[4]) tuple(%i, %ar)
}

ENTRY %main (a: f32[4]) -> (s32[], f32[4]) {
  %a = f32[4] parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[4]) tuple(%c0, %a)
  ROOT %w = (s32[], f32[4]) while(%t0), condition=%cond, body=%body
}
"""


def test_hlo_analyzer_trip_counts():
    st = analyze(HLO)
    # all-reduce of f32[4] = 16B, wire 2x, 7 trips
    assert st.coll_bytes == pytest.approx(2 * 16 * 7)
    assert st.coll_count == 7
    assert "all-reduce" in st.coll_by_type


def test_shape_bytes():
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], s8[8])") == 24
    assert _shape_bytes("pred[]") == 1


def test_roofline_dominant():
    from repro.launch.hlo_analysis import HLOStats
    st = HLOStats(flops=197e12, hbm_bytes=819e9 * 3, coll_bytes=50e9 * 2)
    terms = roofline(st)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert dominant(terms) == "memory"


def test_reduced_configs_are_small():
    from repro.configs.registry import ARCH_IDS, get_smoke
    for a in ARCH_IDS:
        cfg = get_smoke(a)
        from repro.models.model import Model
        assert Model(cfg).param_count() < 30e6, a


def test_group_stride_classification():
    from repro.launch.hlo_analysis import _group_stride
    # explicit list, stride 16 => client axis
    assert _group_stride("replica_groups={{0,16,32,48},{1,17,33,49}}") == 16
    # contiguous iota => model axis
    assert _group_stride("replica_groups=[16,16]<=[256]") == 1
    # strided iota (data axis of a (16,16) mesh)
    assert _group_stride("replica_groups=[16,16]<=[16,16]T(1,0)") == 16
    # model-subgroup with inner transpose (from the qwen attention HLO)
    assert _group_stride("replica_groups=[32,8]<=[16,8,2]T(0,2,1)") == 2


def test_fl_variants_cover_paper_and_beyond():
    from repro.launch.dryrun import FL_VARIANTS
    assert {"baseline", "qsgd8", "stc", "topk", "hier"} <= set(FL_VARIANTS)
    assert FL_VARIANTS["baseline"].uplink_compressor == "none"
    assert FL_VARIANTS["hier"].hierarchical
    # §Perf: hier compresses the DCN hop only
    assert FL_VARIANTS["hier"].uplink_compressor == "none"
    assert FL_VARIANTS["hier"].pod_compressor != "none"
