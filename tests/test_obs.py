"""Flight-recorder differential harness (DESIGN.md §12).

The headline claim, in the style of tests/test_secure_agg.py: turning
``FLConfig.telemetry`` on must change **nothing** the run computes —
params, comm_state, and the CommLedger stay bit-exact on every topology,
because the telemetry hop only reads values the round program already
produced.  Around that anchor:

  * per-stage byte attribution sums to the ledger wire totals exactly in
    f32 (residual construction) and matches the direct f64 stage sum;
  * ResidualStore.stats counters agree with the slab's actual hit/evict
    behaviour, and the staleness histogram is a faithful scatter-add;
  * eval-cadence NaN gaps survive RoundStats stacking, serialize to JSON
    null, and render as ``-`` in the report;
  * the JSONL trace validates against schema v1 and the report renders
    every section it promises;
  * ``launch.hlo_analysis.name_stage_mismatch`` blames the right stage
    for a synthetic collective-bytes gap.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress.residual_store import ResidualStore
from repro.configs.registry import get_arch
from repro.core.engine import (Topology, make_round_engine, run_rounds,
                               uplink_pipeline)
from repro.core.population import ClientPopulation
from repro.core.types import FLConfig
from repro.data.pipeline import cohort_data_fn
from repro.data.synthetic import FedDataConfig, sample_round
from repro.obs.report import render, summarize
from repro.obs.telemetry import (N_STALENESS_BUCKETS, round_stats,
                                 stage_byte_table, staleness_hist,
                                 telemetry_spec, zero_stats)
from repro.obs.trace import (SCHEMA_VERSION, Tracer, validate_file,
                             validate_record)

CFG = get_arch("paper_lm")
DATA = FedDataConfig(vocab_size=CFG.vocab_size, num_clients=4, seq_len=32,
                     batch_per_client=2, heterogeneity=1.5)


def _data_fn(r):
    return sample_round(DATA, jax.random.fold_in(jax.random.PRNGKey(1), r))


def _run(spec, topo_fn, pop=None, n=3, telemetry=False, data_fn=None,
         **fl_kw):
    from repro.models.model import Model
    model = Model(CFG)
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor=spec, telemetry=telemetry, **fl_kw)
    dfn = data_fn or _data_fn
    e = make_round_engine(model, fl, topo_fn(), chunk=32, data_fn=dfn,
                          population=pop)
    st = e.init_fn(jax.random.PRNGKey(0))
    st, ms = run_rounds(e, st, dfn, n, chunk=1, donate=False)
    return e, st, ms


def _assert_leaves_equal(what, a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), f"{what}: leaf count diverged"
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y),
                              equal_nan=True), f"{what} diverged"


# ---------------------------------------------------------------------------
# differential: telemetry on/off is bit-exact everywhere
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    "topk:0.25>>qsgd:8",            # stateful EF chain
    "topk:0.25@kernel>>qsgd:8",     # same chain through the Pallas path
    "qsgd:4>>secagg",               # masked integer wire
    "qsgd:4@fused",                 # bit-packed wire format
])
def test_telemetry_off_path_bitexact_sim(spec):
    off = _run(spec, lambda: Topology.sim(4))
    on = _run(spec, lambda: Topology.sim(4), telemetry=True)
    _assert_leaves_equal(f"sim/{spec} params", off[1].params,
                         on[1].params)
    _assert_leaves_equal(f"sim/{spec} comm_state", off[1].comm_state,
                         on[1].comm_state)
    _assert_leaves_equal(f"sim/{spec} ledger", off[2]["ledger"],
                         on[2]["ledger"])
    assert "round_stats" not in off[2] and "round_stats" in on[2]


def test_telemetry_off_path_bitexact_async():
    topo = lambda: Topology.async_(4, buffer_size=2,
                                   latency_profile="heavy_tail")
    off = _run("topk:0.25>>qsgd:8", topo, n=6)
    on = _run("topk:0.25>>qsgd:8", topo, n=6, telemetry=True)
    _assert_leaves_equal("async params", off[1].params, on[1].params)
    _assert_leaves_equal("async comm_state", off[1].comm_state,
                         on[1].comm_state)
    _assert_leaves_equal("async ledger", off[2]["ledger"], on[2]["ledger"])
    rs = on[2]["round_stats"]
    # one arrival per event: each histogram row is a one-hot
    assert np.allclose(np.asarray(rs.staleness_hist).sum(axis=1), 1.0)
    assert (np.asarray(rs.buffer_fill) >= 1.0).all()


def test_telemetry_off_path_bitexact_population():
    pop = lambda: ClientPopulation(n_clients=32, cohort=8, capacity=12)
    dcfg = FedDataConfig(vocab_size=CFG.vocab_size, num_clients=32,
                         seq_len=32, batch_per_client=2, heterogeneity=1.5)
    outs = []
    for tele in (False, True):
        p = pop()
        outs.append(_run("topk:0.25>>qsgd:8", lambda: Topology.sim(32),
                         pop=p, telemetry=tele,
                         data_fn=cohort_data_fn(p, dcfg)))
    off, on = outs
    _assert_leaves_equal("pop params", off[1].params, on[1].params)
    _assert_leaves_equal("pop comm_state", off[1].comm_state,
                         on[1].comm_state)
    _assert_leaves_equal("pop ledger", off[2]["ledger"], on[2]["ledger"])
    rs = on[2]["round_stats"]
    # 8-client cohorts over a cold 12-slot store: first round all misses,
    # and hits + misses == cohort every round
    hm = np.asarray(rs.store_hits) + np.asarray(rs.store_misses)
    assert np.allclose(hm, 8.0)
    assert float(np.asarray(rs.store_hits)[0]) == 0.0
    assert np.allclose(np.asarray(rs.selected), 8.0)
    assert np.allclose(np.asarray(rs.available), 8.0)


# ---------------------------------------------------------------------------
# per-stage byte attribution sums exactly to the ledger
# ---------------------------------------------------------------------------

def _residual_exact(slots, totals):
    """The committed exactness predicate: f32 sequential reconstruction of
    every row lands bit-equal on the ledger total."""
    for i in range(slots.shape[0]):
        partial = np.float32(0.0)
        for v in slots[i][:-1]:
            partial = np.float32(partial + np.float32(v))
        if slots[i][-1] != np.float32(np.float32(totals[i]) - partial):
            return False
    return True


@pytest.mark.parametrize("spec", ["topk:0.05>>qsgd:8", "qsgd:4>>secagg"])
def test_stage_bytes_sum_to_ledger(spec):
    e, _, ms = _run(spec, lambda: Topology.sim(4), telemetry=True)
    up = np.asarray(ms["round_stats"].up_stage_bytes)
    dn = np.asarray(ms["round_stats"].down_stage_bytes)
    uw = np.asarray(ms["ledger"].uplink_wire)
    dw = np.asarray(ms["ledger"].downlink_wire)
    assert _residual_exact(up, uw) and _residual_exact(dn, dw)
    assert np.allclose(up.astype(np.float64).sum(1), uw, rtol=1e-6)
    assert np.allclose(dn.astype(np.float64).sum(1), dw, rtol=1e-6)
    tele = e.aux["telemetry"]
    assert len(tele.up_names) == up.shape[1]
    # the static per-unit table itself covers the whole wire: 4 clients x
    # up_total() matches the billed uplink within float-sum slack
    assert np.allclose(4.0 * tele.up_total(), uw, rtol=1e-5)


def test_stage_byte_table_matches_wire_bits():
    fl = FLConfig(uplink_compressor="topk:0.1>>qsgd:8")
    pipe = uplink_pipeline(fl)
    sizes = [1000, 4096, 33]
    table = stage_byte_table(pipe, sizes)
    direct = sum(pipe.wire_bits(n) for n in sizes) / 8.0
    assert sum(table) == pytest.approx(direct, rel=1e-9)
    # scale is linear
    assert sum(stage_byte_table(pipe, sizes, scale=3.0)) == \
        pytest.approx(3.0 * direct, rel=1e-9)


def test_telemetry_spec_extra_slot_is_residual_anchor():
    fl = FLConfig(uplink_compressor="qsgd:8")
    spec = telemetry_spec(uplink_pipeline(fl), None, [256],
                          extra_up=(("pod:qsgd8", 1234.0),))
    assert spec.up_names[-1] == "pod:qsgd8"
    assert spec.up_table[-1] == 1234.0
    assert spec.down_names == ("none",)
    z = zero_stats(spec)
    assert z.up_stage_bytes.shape == (len(spec.up_table),)
    assert z.staleness_hist.shape == (N_STALENESS_BUCKETS,)


def test_staleness_hist_scatter():
    # scalar -> one-hot in the right bucket (edges 1,2,4,8,16,32,64)
    assert np.argmax(np.asarray(staleness_hist(0.0))) == 0
    assert np.argmax(np.asarray(staleness_hist(1.0))) == 1
    assert np.argmax(np.asarray(staleness_hist(63.0))) == 6
    assert np.argmax(np.asarray(staleness_hist(1e6))) == 7
    # vector + occupancy weights: masked slots don't count
    h = np.asarray(staleness_hist(jnp.asarray([0.0, 3.0, 3.0, 99.0]),
                                  weights=jnp.asarray([1.0, 1.0, 1.0, 0.0])))
    assert h[0] == 1.0 and h[2] == 2.0 and h[7] == 0.0 and h.sum() == 3.0


def test_round_stats_defaults_zero():
    fl = FLConfig(uplink_compressor="qsgd:8")
    spec = telemetry_spec(uplink_pipeline(fl), None, [64])
    ledger = type("L", (), {"uplink_wire": jnp.float32(sum(spec.up_table)),
                            "downlink_wire": jnp.float32(0.0)})()
    rs = round_stats(spec, ledger, up_unit=jnp.float32(1.0))
    assert float(rs.store_hits) == 0.0 and float(rs.buffer_fill) == 0.0
    assert float(np.asarray(rs.up_stage_bytes).sum()) == \
        pytest.approx(sum(spec.up_table))


# ---------------------------------------------------------------------------
# ResidualStore.stats agrees with the slab
# ---------------------------------------------------------------------------

def _store(capacity=4, eviction="drop"):
    pipe = uplink_pipeline(FLConfig(uplink_compressor="topk:0.25>>qsgd:8"))
    params = {"w": jnp.zeros((40,), jnp.float32)}
    return ResidualStore(pipe, params, capacity, eviction=eviction)


@pytest.mark.parametrize("eviction", ["drop", "sketch"])
def test_store_stats_counters(eviction):
    store = _store(capacity=4, eviction=eviction)
    st = store.init()
    ids0 = jnp.asarray([0, 1, 2, 3], jnp.int32)
    s0 = store.stats(st, ids0)
    assert float(s0["hits"]) == 0.0 and float(s0["misses"]) == 4.0
    assert float(s0["evictions"]) == 0.0      # cold slab: free slots only
    rows, _ = store.gather(st, ids0)
    st = store.scatter(st, ids0, rows)
    # 2 residents + 2 strangers on a full slab: 2 hits, 2 evicting misses
    s1 = store.stats(st, jnp.asarray([0, 1, 7, 9], jnp.int32))
    assert float(s1["hits"]) == 2.0 and float(s1["misses"]) == 2.0
    assert float(s1["evictions"]) == 2.0
    want = 2.0 if eviction == "sketch" else 0.0
    assert float(s1["sketch_recovered"]) == want


def test_availability_count():
    full = ClientPopulation(n_clients=32, cohort=8)
    ids = jnp.arange(8, dtype=jnp.int32)
    assert float(full.availability_count(jnp.int32(0), ids)) == 8.0
    churn = ClientPopulation(n_clients=32, cohort=8, availability=0.5)
    c = float(churn.availability_count(jnp.int32(3), ids))
    assert 0.0 <= c <= 8.0


# ---------------------------------------------------------------------------
# eval cadence: NaN gaps survive stacking, serialization, and rendering
# ---------------------------------------------------------------------------

def test_eval_cadence_nan_stacking_and_report(tmp_path):
    def metrics_fn(state, m):
        return dict(m, eval_loss=jnp.float32(1.5))

    from repro.models.model import Model
    model = Model(CFG)
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="topk:0.25>>qsgd:8", telemetry=True,
                  eval_every=2)
    e = make_round_engine(model, fl, Topology.sim(4), chunk=32)
    st = e.init_fn(jax.random.PRNGKey(0))
    st, ms = run_rounds(e, st, _data_fn, 4, chunk=2, donate=False,
                        metrics_fn=metrics_fn)
    ev = np.asarray(ms["eval_loss"])
    assert np.isnan(ev).any() and np.isfinite(ev).any()
    # RoundStats leaves never gap — they are base metrics in both branches
    for leaf in jax.tree.leaves(ms["round_stats"]):
        assert np.isfinite(np.asarray(leaf)).all()

    path = tmp_path / "cadence.jsonl"
    tr = Tracer(str(path), meta=dict(arch="paper_lm"))
    tr.emit_rounds(ms, spec=e.aux["telemetry"])
    tr.close()
    records = validate_file(str(path))
    rounds = [r for r in records if r["kind"] == "round"]
    assert len(rounds) == 4
    gaps = [r["m"]["eval_loss"] for r in rounds]
    assert None in gaps and 1.5 in gaps         # NaN -> JSON null
    report = render(summarize(records))
    line = next(ln for ln in report.splitlines() if "eval_loss" in ln)
    assert " - " in f" {line} "                  # gap renders as '-'


# ---------------------------------------------------------------------------
# trace schema + report sections
# ---------------------------------------------------------------------------

def test_trace_schema_and_report_sections(tmp_path):
    path = tmp_path / "run.jsonl"
    tr = Tracer(str(path), meta=dict(arch="smoke", topology="sim"))
    with tr.span("chunk", rounds=2) as sp:
        sp["kind"] = "compile"                   # mutable retag
    with tr.span("eval"):
        pass
    tr.event("flush", round=3)
    e, _, ms = _run("topk:0.25>>qsgd:8", lambda: Topology.sim(4),
                    telemetry=True)
    tr.emit_rounds(ms, spec=e.aux["telemetry"])
    tr.close()

    records = validate_file(str(path))
    assert records[0]["kind"] == "meta"
    assert records[0]["schema"] == SCHEMA_VERSION
    kinds = [r["kind"] for r in records]
    assert "compile" in kinds and "chunk" not in kinds
    assert "flush" in kinds and "stages" in kinds
    assert sum(k == "round" for k in kinds) == 3

    report = render(summarize(records))
    for section in ("uplink byte waterfall", "time breakdown",
                    "claims-ready rows"):
        assert section in report, f"report lost its {section!r} section"
    md = render(summarize(records), md=True)
    assert md != report


def test_validate_record_rejects_malformed():
    with pytest.raises(ValueError, match="schema version"):
        validate_record({"v": 999, "kind": "meta"})
    with pytest.raises(ValueError, match="dur_s"):
        validate_record({"v": SCHEMA_VERSION, "kind": "chunk",
                         "type": "span"})
    with pytest.raises(ValueError, match="metrics dict"):
        validate_record({"v": SCHEMA_VERSION, "kind": "round", "round": 0})
    with pytest.raises(ValueError, match="kind"):
        validate_record({"v": SCHEMA_VERSION})


def test_validate_file_requires_meta_header(tmp_path):
    p = tmp_path / "headless.jsonl"
    p.write_text(json.dumps({"v": 1, "kind": "event", "type": "event"})
                 + "\n")
    with pytest.raises(ValueError, match="meta header"):
        validate_file(str(p))


# ---------------------------------------------------------------------------
# HLO mismatch attribution
# ---------------------------------------------------------------------------

def test_name_stage_mismatch():
    from repro.launch.hlo_analysis import name_stage_mismatch
    names = ("topk", "qsgd8")
    table = (900.0, 2100.0)
    # agreement within rtol -> silent
    assert name_stage_mismatch(names, table, measured=3000.0) == ""
    assert name_stage_mismatch(names, table, measured=3050.0) == ""
    # the whole qsgd8 payload missing from the collective
    msg = name_stage_mismatch(names, table, measured=900.0)
    assert "qsgd8" in msg and "missing from" in msg
    # the topk meta double-counted
    msg = name_stage_mismatch(names, table, measured=3900.0)
    assert "topk" in msg and "over-counted" in msg
    # explicit expected_total overrides the table sum
    assert name_stage_mismatch(names, table, measured=5000.0,
                               expected_total=5000.0) == ""


# ---------------------------------------------------------------------------
# Star driver compiles once per chunk shape
# ---------------------------------------------------------------------------

def test_star_runner_single_compile_per_chunk_shape():
    # Regression: the star RoundRunner used to recompile every chunk after
    # the first, because donated outputs came back with fully-replicated
    # shardings that no longer matched the jit's inferred input shardings.
    # Pinning out_shardings (and device_put-ing the carried state) keeps
    # the executable cache at exactly one entry across same-shape chunks.
    from repro.core.engine import RoundRunner
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import Model
    mesh = make_host_mesh(model=1)
    model = Model(CFG)
    fl = FLConfig(algorithm="fedavg", local_steps=1, local_lr=0.2,
                  uplink_compressor="topk:0.25>>qsgd:8")
    e = make_round_engine(model, fl, Topology.star(), mesh=mesh, chunk=32)
    star_data = FedDataConfig(vocab_size=CFG.vocab_size,
                              num_clients=e.n_clients, seq_len=32,
                              batch_per_client=2)

    def data_fn(r):
        return sample_round(star_data,
                            jax.random.fold_in(jax.random.PRNGKey(1), r))

    runner = RoundRunner(e, data_fn, chunk=2)
    st = e.init_fn(jax.random.PRNGKey(0))
    st, _ = runner.run(st, 4)  # two chunks of the same shape
    n = runner.cache_size()
    if n is None:
        pytest.skip("jit cache size introspection unavailable on this jax")
    assert n == 1, f"star runner recompiled: {n} executables for one shape"
