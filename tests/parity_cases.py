"""Shared differential-parity case table for the kernel wire backend.

One table drives the whole harness (tests/test_kernel_parity.py): every
kernel-capable stage, the combined-sweep chains, and the stateful EF/DGC
wrappers, each run through BOTH backends on identical inputs. The same
table validates unchanged on real TPU — the kernels pick interpret mode vs
Mosaic from ``jax.default_backend()`` (``repro.kernels.ops._interpret``).

Parity classes (DESIGN.md §6):

  * ``exact=True``  — the kernel is deterministic and the blocked layout
    does not reorder any reduction: decoded payloads, comm_state, and
    ledger bytes must match BIT-EXACTLY (qsgd: shared uniforms sampled in
    the pure blocked layout; topk: lax.top_k tie order preserved through
    the masking pass).
  * ``exact=False`` — padding/blocking reorders a reduction (ternary's mu
    partial sums, count-sketch's per-chunk matmul accumulation): decoded
    payloads and state match within ``tol`` (relative, against the input
    scale), signs/supports still exactly.

``sizes`` sweeps n across the kernel layout boundaries: below one block,
non-multiples of block and of block*ROWS, and an exact grid multiple.
"""
import jax
import jax.numpy as jnp


# n values vs the kernel blocking (block=2048 unless a case overrides it,
# grid rows padded to multiples of ROWS=8): sub-block, ragged, exact grid.
SIZES = (100, 3001, 5000, 8 * 2048)


def gaussian(seed, n):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 2.0


def heavy_hitters(seed, n):
    """Planted heavy hitters over small noise — sketch decode recovers a
    stable top-k support, so near-tie selection flips cannot mask a real
    parity break."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = 0.01 * jax.random.normal(k1, (n,))
    m = max(4, n // 100)
    idx = jax.random.choice(k2, n, (m,), replace=False)
    spikes = jnp.where(jnp.arange(m) % 2 == 0, 1.0, -1.0) * \
        (5.0 + jnp.arange(m, dtype=jnp.float32))
    return x.at[idx].set(spikes)


INPUTS = {"gaussian": gaussian, "hh": heavy_hitters}


def case(name, spec, *, exact=True, tol=0.0, input="gaussian",
         wrapper=None, rounds=1, kw=None, sizes=SIZES, base=None):
    return dict(name=name, spec=spec, exact=exact, tol=tol, input=input,
                wrapper=wrapper, rounds=rounds, kw=kw or {}, sizes=sizes,
                base=base)


# --- every kernel-capable stage, standalone --------------------------------
STAGE_CASES = [
    case("topk", "topk:0.05"),
    case("qsgd8", "qsgd:8"),
    case("qsgd4", "qsgd:4"),
    case("qsgd_block256", "qsgd:8,256"),
    case("ternary", "ternary", exact=False, tol=1e-5),
    case("stc", "stc:0.05", exact=False, tol=1e-5),
    case("sketch", "sketch:3,512", exact=False, tol=1e-3, input="hh"),
]

# --- chained specs from the combined-scheme sweep --------------------------
CHAIN_CASES = [
    case("topk_qsgd8", "topk:0.01>>qsgd:8"),
    case("topk_qsgd4", "topk:0.05>>qsgd:4"),
    case("topk_ternary", "topk:0.1>>ternary", exact=False, tol=1e-5),
    case("sketch_qsgd8", "sketch:3,512>>qsgd:8", exact=False, tol=1e-3,
         input="hh"),
]

# --- EF / DGC momentum wrappers (comm_state evolution across rounds) -------
WRAPPER_CASES = [
    case("ef_topk_qsgd", "topk:0.05>>qsgd:8", wrapper="ef", rounds=3),
    case("ef_stc", "stc:0.05", wrapper="ef", exact=False, tol=1e-5,
         rounds=3),
    case("mc_topk", "topk", wrapper="mc", rounds=3,
         kw=dict(fraction=0.05)),
    case("mc_warmup_topk", "topk", wrapper="mc_warmup", rounds=4,
         kw=dict(fraction=0.02), sizes=(3001, 5000)),
]

# --- packed wire formats ("@fused", DESIGN.md §10) -------------------------
# The packed payload bytes must be BIT-equal across backends (the fused
# pack kernels emit exactly wire_format.pack2/pack4 of the staged codes) —
# test_backend_parity's integer-dtype comparison enforces that on the
# payloads via comm_state/decode; the dedicated round-trip tests in
# test_kernel_parity.py cover the raw byte streams. mu partial sums keep
# the bounded-ULP class of their staged twins.
FUSED_CASES = [
    case("ternary_fused", "ternary@fused", exact=False, tol=1e-5),
    case("qsgd4_fused", "qsgd:4@fused"),
    case("qsgd2_fused", "qsgd:2@fused"),
    case("stc_fused", "stc:0.1@fused", exact=False, tol=1e-5),
    case("topk_qsgd4_fused", "topk:0.05>>qsgd:4@fused"),
    case("topk_ternary_fused", "topk:0.1>>ternary@fused", exact=False,
         tol=1e-5),
    case("ef_stc_fused", "stc:0.1@fused", wrapper="ef", exact=False,
         tol=1e-5, rounds=3),
]

# --- privacy stages (secagg masking / dpnoise, DESIGN.md §11) --------------
# Each privacy case pairs a masked spec with its clear ``base`` spec: the
# kernel-parity run exercises the masked pipeline through both backends
# (ALL_CASES membership), and tests/test_secure_agg.py additionally runs
# the masked-vs-base differential (bit-exact decode after mask removal,
# identical ledger wire bytes).  dpnoise:0 with an inf clip is the proven
# bit-exact no-op, so its masked-vs-base differential is also exact.
PRIVACY_CASES = [
    case("secagg_qsgd4", "qsgd:4>>secagg", base="qsgd:4"),
    case("secagg_topk_qsgd", "topk:0.05>>qsgd:4>>secagg",
         base="topk:0.05>>qsgd:4"),
    case("secagg_ternary_fused", "ternary@fused>>secagg",
         base="ternary@fused", exact=False, tol=1e-5),
    case("secagg_ef_chain", "topk:0.05>>qsgd:8>>secagg",
         base="topk:0.05>>qsgd:8", wrapper="ef", rounds=3),
    case("secagg_qsgd2_fused", "qsgd:2@fused>>secagg", base="qsgd:2@fused"),
]

ALL_CASES = (STAGE_CASES + CHAIN_CASES + WRAPPER_CASES + FUSED_CASES
             + PRIVACY_CASES)


def build(c, backend):
    """Materialise one case's pipeline for a backend."""
    from repro.compress import make_compressor
    from repro.compress.pipeline import error_feedback, momentum_correction
    if c["wrapper"] == "mc_warmup":
        # warm-up widens the wire capacity; the annealed mask shrinks the
        # effective support inside it (pipeline.MomentumCorrection)
        target = c["kw"].get("fraction", 0.02)
        warmup = 2
        wide = target ** (1.0 / (warmup + 1.0))
        return momentum_correction(
            make_compressor(c["spec"], backend=backend, fraction=wide),
            momentum=0.9, warmup_rounds=warmup, final_fraction=target)
    pipe = make_compressor(c["spec"], backend=backend, **c["kw"])
    if c["wrapper"] == "ef":
        pipe = error_feedback(pipe)
    elif c["wrapper"] == "mc":
        pipe = momentum_correction(pipe, momentum=0.9)
    return pipe


# --- scenario conformance cases (core.scenario, DESIGN.md §13) -------------
# Each entry is a *degenerate-but-enabled* scenario: the dynamics hops ARE
# in the graph (Scenario.enabled is True, so this is not the trivial
# statically-skipped path) but every mask they draw is the identity — the
# square/diurnal traces at duty 1.0 emit all-ones, the epoch-scale floor
# 1.0 clips every client to the full local_steps budget.  The conformance
# harness (tests/test_scenario.py) asserts params, comm_state, and ledger
# bytes stay BIT-EXACT vs the scenario-free engines across these wire
# specs — including the Pallas kernel path, the bit-packed fused wire, and
# the secagg masked wire.
def scenario_case(name, spec, **fl_kw):
    return dict(name=name, spec=spec, fl=fl_kw)


SCENARIO_CASES = [
    scenario_case("square_duty1_ef", "topk:0.25>>qsgd:8",
                  scenario_trace="square"),
    scenario_case("diurnal_rate1_kernel", "topk:0.25@kernel>>qsgd:8",
                  scenario_trace="diurnal"),
    scenario_case("escale_floor1_fused", "qsgd:4@fused",
                  scenario_epoch_scale=1.0),
    scenario_case("square_duty1_secagg", "qsgd:4>>secagg",
                  scenario_trace="square"),
    scenario_case("diurnal_escale_combo", "topk:0.25>>qsgd:8",
                  scenario_trace="diurnal", scenario_epoch_scale=1.0),
]
