"""Docs-lint: every CommPipeline spec string quoted in the docs must parse.

README.md, DESIGN.md and EXPERIMENTS.md quote spec strings
("topk:0.01>>qsgd:8", "stc@kernel", ...) as reproduce commands and grammar
examples. Docs rot silently when the grammar moves, so this test extracts
every chained ("...>>...") or backend-suffixed ("...@kernel") spec-shaped
token from the three docs and asserts ``make_compressor`` builds it — the
same gate the ``docs-lint`` CI job runs. A doc referencing a stage that was
renamed or a suffix that no longer exists fails here, not in a reader's
shell.
"""
import os
import re

import pytest

from repro.compress.api import make_compressor

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["README.md", "DESIGN.md", "EXPERIMENTS.md"]

# one pipeline stage: name[:num[,num...]][@suffix]* — suffixes stack
# (backend @jax/@kernel and wire format @fused, DESIGN.md §3/§10)
_STAGE = r"[a-z][a-z0-9_]*(?::[0-9]+(?:\.[0-9]+)?(?:,[0-9]+(?:\.[0-9]+)?)*)?(?:@[a-z]+)*"
# a lintable spec: either a chain (>= one ">>") or a single @-suffixed stage
_SPEC = re.compile(rf"^(?:{_STAGE}(?:>>{_STAGE})+|{_STAGE}@[a-z]+(?:>>{_STAGE})*)$")
# candidates live in double quotes or backtick code spans
_QUOTED = re.compile(r'["`]([^"`\s]+)["`]')


def _extract(text: str):
    """Spec-shaped tokens from quoted/backticked spans of a markdown doc."""
    out = []
    for tok in _QUOTED.findall(text):
        # strip a wrapping quote layer ("`\"topk:0.01>>qsgd:8\"`" nesting)
        tok = tok.strip('"').strip("'")
        if (">>" in tok or "@" in tok) and _SPEC.match(tok):
            out.append(tok)
    return out


def _doc_specs():
    cases = []
    for doc in DOCS:
        path = os.path.join(ROOT, doc)
        assert os.path.exists(path), (
            f"{doc} is referenced by the docs-lint contract but missing")
        with open(path) as fh:
            for spec in _extract(fh.read()):
                cases.append(pytest.param(doc, spec, id=f"{doc}:{spec}"))
    return cases


def test_docs_quote_at_least_one_spec_each():
    """The extraction itself must not rot: each doc quotes >= 1 spec (README
    quickstart, DESIGN grammar examples, EXPERIMENTS reproduce commands)."""
    for doc in DOCS:
        with open(os.path.join(ROOT, doc)) as fh:
            assert _extract(fh.read()), f"{doc}: no spec strings extracted"


@pytest.mark.parametrize("doc,spec", _doc_specs())
def test_doc_spec_parses(doc, spec):
    comp = make_compressor(spec, fraction=0.01)
    # a parsed pipeline must also account bytes — the docs quote specs in
    # wire-cost claims, so a spec that builds but cannot size payloads
    # (grammar drift in a stage factory) still rots the doc
    assert comp.wire_bits(1 << 12) > 0 or comp.is_identity, (doc, spec)


# ---------------------------------------------------------------------------
# benchmark-suite references: every `--only <x>` in the docs must exist
# ---------------------------------------------------------------------------

_ONLY = re.compile(r"--only[= ]([a-zA-Z0-9_,]+)")


def _registered_suites():
    """The BENCHES registry out of benchmarks/run.py without running it
    (the module guards execution behind __main__)."""
    import importlib.util
    path = os.path.join(ROOT, "benchmarks", "run.py")
    spec = importlib.util.spec_from_file_location("benchmarks_run", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return set(mod.BENCHES)


def _only_refs():
    cases = []
    for doc in DOCS + ["ROADMAP.md"]:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            continue
        with open(path) as fh:
            for m in _ONLY.finditer(fh.read()):
                for suite in m.group(1).split(","):
                    cases.append(pytest.param(doc, suite,
                                              id=f"{doc}:{suite}"))
    return cases


def test_docs_reference_at_least_one_suite():
    assert _only_refs(), "no `--only <suite>` references extracted"


@pytest.mark.parametrize("doc,suite", _only_refs())
def test_doc_only_suite_is_registered(doc, suite):
    """A doc advertising ``benchmarks --only <x>`` for a suite that was
    renamed or never registered rots in a reader's shell; fail here."""
    assert suite in _registered_suites(), (
        f"{doc} references benchmark suite {suite!r}; "
        f"registered: {sorted(_registered_suites())}")


# ---------------------------------------------------------------------------
# observability flags (DESIGN.md §12): docs advertise `--trace` /
# `--profile-dir` on repro.launch.train; those flags must exist in the
# argparse source, and the docs must actually quote them
# ---------------------------------------------------------------------------

_FLAG = re.compile(r"add_argument\(\s*\"(--[a-z][a-z0-9-]*)\"")


def _train_flags():
    """Long flags out of launch/train.py's argparse, by source scan — the
    module's main() builds the parser lazily, so import alone won't do."""
    path = os.path.join(ROOT, "src", "repro", "launch", "train.py")
    with open(path) as fh:
        return set(_FLAG.findall(fh.read()))


def _doc_train_flags():
    """Every `--flag` quoted in a doc line that mentions the train CLI."""
    refs = []
    for doc in DOCS:
        with open(os.path.join(ROOT, doc)) as fh:
            for line in fh:
                if "repro.launch.train" not in line:
                    continue
                for flag in re.findall(r"--[a-z][a-z0-9-]*", line):
                    refs.append(pytest.param(doc, flag,
                                             id=f"{doc}:{flag}"))
    return refs


def test_docs_quote_the_obs_flags():
    """The Observability quickstart must actually advertise the flight
    recorder: `--trace` and `--profile-dir` each quoted by >= 1 doc."""
    quoted = {flag for p in _doc_train_flags() for _, flag in [p.values]}
    assert "--trace" in quoted and "--profile-dir" in quoted, quoted


def test_docs_quote_the_scenario_flags():
    """The Client-dynamics quickstart must advertise the scenario pack:
    the `--scenario-*` family exists in the train CLI and at least the
    trace/dropout knobs are quoted by a doc."""
    defined = {f for f in _train_flags() if f.startswith("--scenario-")}
    assert {"--scenario-trace", "--scenario-availability",
            "--scenario-dropout", "--scenario-epoch-scale",
            "--scenario-deadline-quantile"} <= defined, defined
    quoted = {flag for p in _doc_train_flags() for _, flag in [p.values]}
    assert "--scenario-trace" in quoted, quoted
    assert "--scenario-dropout" in quoted, quoted


@pytest.mark.parametrize("doc,flag", _doc_train_flags())
def test_doc_train_flag_exists(doc, flag):
    """A doc advertising a train-CLI flag that was renamed or removed rots
    in a reader's shell; fail here against the argparse source."""
    assert flag in _train_flags(), (
        f"{doc} quotes train flag {flag!r}; "
        f"defined: {sorted(_train_flags())}")


# ---------------------------------------------------------------------------
# privacy grammar (DESIGN.md §11): EXPERIMENTS §Privacy quotes secagg /
# dpnoise specs; they must build, and the unmaskable combination must fail
# with an error that names the fix
# ---------------------------------------------------------------------------

def _privacy_section_specs():
    with open(os.path.join(ROOT, "EXPERIMENTS.md")) as fh:
        text = fh.read()
    m = re.search(r"^## §Privacy.*?(?=^## |\Z)", text, re.M | re.S)
    assert m, "EXPERIMENTS.md lost its §Privacy section"
    return _extract(m.group(0))


def test_experiments_privacy_section_quotes_privacy_specs():
    """§Privacy must quote at least one secagg spec and one dpnoise spec —
    the reproduce commands the section stands on — and each must build."""
    specs = _privacy_section_specs()
    assert any(">>secagg" in s for s in specs), specs
    assert any("dpnoise:" in s for s in specs), specs
    for spec in specs:
        comp = make_compressor(spec, fraction=0.01)
        assert comp.wire_bits(1 << 12) > 0 or comp.is_identity, spec


def test_secagg_over_float_payload_names_carrier():
    """The guard every §Privacy reader will eventually hit: secagg over a
    float payload (no integer code plane to mask) must refuse, naming the
    quantizing carrier to add rather than failing downstream."""
    with pytest.raises(ValueError) as e:
        make_compressor("topk:0.05>>secagg")
    msg = str(e.value)
    assert "quantizing carrier" in msg and "qsgd:4>>secagg" in msg
