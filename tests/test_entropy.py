"""Entropy-bits ledger cross-check: the pipeline's ``entropy_bits`` uses
Golomb-coded index gaps for sparsifiers, Elias-coded levels for quantizers,
1 bit/sign for ternary — and, for chains, the **carrier-conditional**
composition (each stage's estimate conditioned on the distribution of the
carrier it receives, not just its length).
This suite codes **actual sampled payloads** with a real Golomb-Rice coder
(optimal Rice parameter) and Elias-gamma and asserts the estimate sits inside
a tolerance band of the achieved bits.

Measured bands (Gaussian inputs, n=2^16):
  * sparsifier index estimates are tight (~±10%);
  * chained topk>>qsgd is now tight too (~±10%): the chain's carrier holds
    the largest-magnitude values, whose quantization levels concentrate
    near full scale — exactly where Elias-gamma is expensive
    (~2*log2(2*level)+1 >> the unconditional bits+1/coord). The old
    independent-stage estimate under-counted those chains by ~30% (ratio
    0.7-0.9); the carrier-conditional truncated-tail model (DESIGN.md §1,
    ``meta_entropy_bits_given``) closes that gap.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import make_compressor


# ---------------------------------------------------------------------------
# reference coders (numpy, slow, exact bit counts)
# ---------------------------------------------------------------------------

def golomb_rice_bits(idx, n):
    """Bits to Golomb-Rice-code the sorted index gaps, with the optimal Rice
    parameter b (unary quotient + 1 stop bit + b remainder bits)."""
    idx = np.sort(np.asarray(idx, np.int64))
    gaps = np.diff(idx, prepend=-1)             # first gap = idx[0] + 1
    return min(float(np.sum(gaps // (1 << b) + 1 + b)) for b in range(24))


def elias_gamma_bits(q):
    """Bits to Elias-gamma-code signed integer levels (zigzag to 1-based)."""
    q = np.asarray(q, np.int64).ravel()
    v = 2 * np.abs(q) + (q < 0) + 1
    return float(np.sum(2 * np.floor(np.log2(v)) + 1))


def sign_entropy_bits(sign):
    """Shannon bound for an arithmetic-coded sign stream."""
    s = np.asarray(sign).ravel()
    p = float((s > 0).mean())
    if p in (0.0, 1.0):
        return 0.0
    h = -(p * math.log2(p) + (1 - p) * math.log2(1 - p))
    return s.size * h


def code_payload(payload, n):
    """Total achieved bits for one encoded payload, recursing into chains."""
    total = 0.0
    for k, v in payload.items():
        if isinstance(v, dict):
            total += code_payload(v, n)
        elif k == "idx":
            arr = np.asarray(v)
            total += golomb_rice_bits(arr[arr < n], n)
        elif k == "q":
            total += elias_gamma_bits(v)
        elif k == "sign":
            total += sign_entropy_bits(v)
        elif k in ("seed", "useed"):
            total += 64.0
        else:                                   # scales / mu / raw f32
            total += 32.0 * np.asarray(v).size
    return total


# ---------------------------------------------------------------------------
# the cross-check
# ---------------------------------------------------------------------------

N = 1 << 16
CASES = [
    # (spec, band for estimate/achieved)
    ("topk:0.01", (0.90, 1.15)),                # Golomb formula is tight
    ("topk:0.05", (0.90, 1.20)),
    ("stc", (0.90, 1.20)),                      # + 1 bit/sign
    # SBC's ledger pays Golomb gaps for all k slots, but ~half are dropped
    # minority-sign slots a real coder would never send — conservative ~1.9x
    ("sbc", (1.30, 2.30)),
    # chains: the carrier-conditional model (qsgd levels integrated over the
    # top-k truncated-normal tail) is tight — the pre-conditional
    # independent-stage estimate sat at ratio ~0.7-0.9 here
    ("topk:0.01>>qsgd:8", (0.85, 1.15)),
    ("topk:0.05>>qsgd:4", (0.85, 1.15)),
    ("topk:0.05>>qsgd:8", (0.85, 1.15)),
]


@pytest.mark.parametrize("spec,band", CASES)
def test_entropy_estimate_within_band_of_real_coder(spec, band):
    pipe = make_compressor(spec, fraction=0.01)
    x = jax.random.normal(jax.random.PRNGKey(0), (N,))
    achieved = np.mean([
        code_payload(pipe.compress(jax.random.PRNGKey(s), x), N)
        for s in range(3)])
    est = pipe.entropy_bits(N)
    ratio = est / achieved
    lo, hi = band
    assert lo <= ratio <= hi, (spec, est, achieved, ratio)
    # the real coder must beat the dtype-packed wire (that is its point),
    # and the ledger's entropy column must never exceed the wire column
    assert achieved <= pipe.wire_bits(N)
    assert est <= pipe.wire_bits(N)


def test_chain_entropy_is_carrier_conditional():
    """The ledger's composition law: chain entropy == sum of per-stage
    estimates where each stage is conditioned on the *previous* stage's
    carrier hint — qsgd after topk pays the top-tail Elias cost, which is
    strictly more than its unconditional (generic-input) estimate."""
    n = N
    pipe = make_compressor("topk:0.01>>qsgd:8")
    topk = make_compressor("topk", fraction=0.01)
    qsgd = make_compressor("qsgd8")
    k = max(1, round(n * 0.01))
    hint = topk.carrier_hint(n)
    assert hint == {"kind": "top_tail", "fraction": k / n}
    assert pipe.entropy_bits(n) == pytest.approx(
        topk.meta_entropy_bits(n) + qsgd.meta_entropy_bits_given(k, hint))
    # the conditional estimate must exceed the unconditional one (that is
    # the ~30% under-count it repairs) but never the dtype-packed wire
    assert qsgd.meta_entropy_bits_given(k, hint) > qsgd.meta_entropy_bits(k)
    assert pipe.entropy_bits(n) <= pipe.wire_bits(n)
    # stages with no conditional model ignore the hint (ternary signs stay
    # 1 bit/sign on any carrier)
    tern = make_compressor("stc", fraction=0.01).stages[-1]
    assert tern.meta_entropy_bits_given(k, hint) == tern.meta_entropy_bits(k)
