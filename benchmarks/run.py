"""Benchmark harness — one benchmark per surveyed claim family (the paper is
a survey; its "tables" are method families, and each bench reproduces that
family's headline quantitative claim on the paper-faithful small FL workload).

Output: ``name,us_per_call,derived`` CSV (one row per configuration).

  compression      §III.B.5  wire bytes + fidelity per compressor
  kernels          Pallas kernels (interpret) vs jnp oracle timing
  convergence      §III.B.1  FedAvg vs FedProx vs SCAFFOLD on non-iid [46]
  bytes_to_loss    §III.B.5  loss-vs-cumulative-bytes: compression wins [39,45]
  combined         §III.B.5  combined-scheme sweep: topk fraction x qsgd bits
                   grid + sketch>>qsgd, bytes-to-target-loss (Pareto points)
  selection        §III.B.2  Power-of-Choice vs random [54]
  hierarchy        §III.B.3  flat vs hierarchical sync cost model [45,73]
  async            §III.B    AsyncEngine: FedBuff/FedAsync vs sync FedAvg —
                   virtual wall-clock AND bytes to the same target loss
                   under a heavy-tailed straggler profile (DESIGN.md §7)
  engine           RoundEngine scan driver (run_rounds) vs Python round loop
  roofline         §Dry-run  per-arch roofline terms (reads experiments/)
  privacy          DESIGN.md §11  secagg masking bit-exactness + dpnoise
                   privacy/bytes/accuracy Pareto sweep
  scenario         DESIGN.md §13  client-dynamics scenario pack: trace duty
                   cycles, adaptive deadline convergence, and the
                   sync-vs-FedBuff race under diurnal availability +
                   mid-round dropout

Every ``holds=`` row emitted here must be registered in
``benchmarks/claims.py`` (id + reproduce + tolerance); ``_check_trajectory``
enforces that and fails loudly when a previously-held claim flips.

FL convergence benches run through the RoundEngine scan driver
(``run_rounds``, chunk=8): batches are sampled and the held-out eval loss is
computed *inside* the compiled scan, so a run pays one dispatch per chunk.

Run: ``PYTHONPATH=src python -m benchmarks.run [--only NAME] [--rounds N]``
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

# The fused suite verifies collective bytes on compiled multi-device HLO;
# the host-platform device count must be set BEFORE jax import (same
# constraint as tests/distributed_cases.py), so peek at argv here.
if any("fused" in a for a in sys.argv):
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import make_compressor
from repro.configs.registry import get_arch
from repro.core.engine import run_rounds
from repro.core.simulate import make_sim_step
from repro.core.types import FLConfig
from repro.data.synthetic import FedDataConfig, eval_batch, sample_round
from repro.models.model import Model

ROWS = []
SMOKE = False        # --smoke: tiny CI legs (population 100k only, 2 rounds)


def emit(name, us_per_call, **derived):
    if SMOKE and "holds" in derived:
        # seed-noisy predicates (Claim.smoke=False, e.g. timing races) are
        # meaningless at --smoke scale: keep the row's measurements but drop
        # the holds= verdict so smoke BENCH records and the claims-recheck
        # job never gate on them (benchmarks/claims.py smoke tiers)
        c = _load_claims_registry().lookup(name)
        if c is not None and not c.smoke:
            derived = {k: v for k, v in derived.items() if k != "holds"}
            derived["smoke_verdict"] = "skipped-not-smoke-checkable"
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    ROWS.append(f"{name},{us_per_call:.1f},{d}")
    print(ROWS[-1], flush=True)


def _timeit(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))           # compile/warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


# ---------------------------------------------------------------------------

def bench_compression(rounds):
    n = 1 << 20
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    for name in ["none", "qsgd8", "qsgd4", "uveq", "hsq", "topk", "stc",
                 "sbc", "randmask", "sketch",
                 # chained CommPipelines (combined schemes, one spec string)
                 "topk:0.01>>qsgd:8", "randmask:0.05>>qsgd:8",
                 "sketch>>qsgd:8"]:
        comp = make_compressor(name, fraction=0.01)
        rt = jax.jit(lambda r, v: comp.roundtrip(r, v))
        us = _timeit(rt, jax.random.PRNGKey(1), x)
        y = rt(jax.random.PRNGKey(1), x)
        cos = float((x @ y) / (jnp.linalg.norm(x) * jnp.linalg.norm(y) + 1e-9))
        emit(f"compression/{name}", us,
             wire_mb=round(comp.wire_bits(n) / 8e6, 4),
             entropy_mb=round(comp.entropy_bits(n) / 8e6, 4),
             ratio_vs_f32=round(32.0 * n / comp.wire_bits(n), 2),
             cosine=round(cos, 4))


def bench_kernels(rounds):
    from repro.kernels import ops, ref
    from repro.compress.sketch import hash_params
    n = 1 << 18
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    u = jax.random.uniform(jax.random.PRNGKey(1), (n,))
    xb, _ = ops._to_blocked(x, 2048)
    ub, _ = ops._to_blocked(u, 2048)
    t = jnp.float32(1.0)
    a, b = hash_params(5)

    pairs = [
        ("qsgd", lambda: ops.qsgd_quantize(x, u, 8, 2048),
         lambda: ref.ref_qsgd_quantize_blocked(xb, ub, 8)),
        ("ternary", lambda: ops.stc_ternarize(x, 0.01, 2048),
         lambda: ref.ref_ternarize_blocked(xb, t)),
        ("topk_mask", lambda: ops.threshold_sparsify(x, t, 2048),
         lambda: ref.ref_threshold_sparsify_blocked(xb, t)),
        ("count_sketch", lambda: ops.sketch(x, 5, 4096),
         lambda: ref.ref_count_sketch(x, a, b, 5, 4096)),
    ]
    for name, kfn, rfn in pairs:
        kus = _timeit(kfn)
        rus = _timeit(rfn)
        emit(f"kernels/{name}", kus, ref_us=round(rus, 1),
             note="interpret-mode-on-cpu")

    # stage-level smoke: the kernel wire backend vs pure JAX through the
    # CommPipeline encode on the largest paper_lm leaf — the exact hot path
    # the engine runs when FLConfig.backend="kernel" (DESIGN.md §6). Off-TPU
    # the kernels run interpreted, so kernel_us here gates plumbing+parity,
    # not speed; on TPU the same rows become the fusion claim.
    cfg = get_arch("paper_lm")
    model = Model(cfg)
    n_max = max(int(np.prod(d.shape))
                for d in jax.tree.leaves(model.abstract_params()))
    xl = jax.random.normal(jax.random.PRNGKey(2), (n_max,))
    for spec in ("qsgd:8", "stc:0.01", "topk:0.01>>qsgd:8", "sketch>>qsgd:8"):
        row = {}
        for backend in ("jax", "kernel"):
            comp = make_compressor(spec, fraction=0.01, backend=backend)
            enc = jax.jit(lambda r, v, c=comp:
                          c.encode(c.init(v.shape), r, v)[0])
            row[backend] = _timeit(enc, jax.random.PRNGKey(3), xl)
        emit(f"kernels/pipeline_{spec.replace('>>', '+').replace(':', '')}",
             row["kernel"], jax_us=round(row["jax"], 1), n=n_max,
             note="interpret-mode-on-cpu")


def _fl_run(fl: FLConfig, rounds, het=2.0, clients=8, seed=0, chunk=8):
    """One simulated FL training run through the RoundEngine scan driver:
    data sampling and the held-out eval both live inside the compiled scan."""
    cfg = get_arch("paper_lm")
    model = Model(cfg)
    dcfg = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=clients,
                         seq_len=48, batch_per_client=4, heterogeneity=het,
                         seed=seed)
    sim = make_sim_step(model, fl, clients, chunk=48)
    state = sim.init_fn(jax.random.PRNGKey(seed))
    ev = eval_batch(dcfg, jax.random.PRNGKey(99), batch_size=8)

    def data_fn(r):
        return sample_round(dcfg, jax.random.fold_in(
            jax.random.PRNGKey(seed + 1), r))

    def metrics_fn(state, m):
        m = dict(m)
        m["eval_loss"] = model.loss(state.params, ev, chunk=48)[0]
        return m

    t0 = time.perf_counter()
    state, ms = run_rounds(sim.engine, state, data_fn, rounds, chunk=chunk,
                           metrics_fn=metrics_fn)
    jax.block_until_ready(ms)
    us = (time.perf_counter() - t0) / rounds * 1e6
    losses = [float(x) for x in ms["eval_loss"]]
    per_round = (np.asarray(ms["ledger"].uplink_wire, np.float64)
                 + np.asarray(ms["ledger"].downlink_wire, np.float64))
    return losses, list(np.cumsum(per_round)), us


def _emit_bytes_to_target(prefix, runs, order=None):
    """Shared Pareto read-out: MB to reach the common target loss (worst
    final + margin), with the saving vs the dense baseline."""
    target = max(l[-1] for l, _ in runs.values()) + 0.02
    base_mb = None
    for name in (order or list(runs)):
        losses, bytes_cum = runs[name]
        idx = next((i for i, l in enumerate(losses) if l <= target), None)
        mb = bytes_cum[idx] / 1e6 if idx is not None else float("inf")
        if name == "dense_f32":
            base_mb = mb
        emit(f"{prefix}/target/{name}", 0.0, target=round(target, 3),
             mb_to_target=round(mb, 3),
             saving_vs_dense=(round(base_mb / mb, 2)
                              if mb and base_mb not in (None, 0) else 0))


def bench_convergence(rounds):
    """SCAFFOLD/FedProx vs FedAvg under client drift (non-iid, E=4) on the
    LM task, plus the canonical heterogeneous-quadratic drift construction
    from Karimireddy et al. [46] where the claim is provable."""
    res = {}
    for name, fl in [
        ("fedavg", FLConfig(algorithm="fedavg", local_steps=4, local_lr=0.2)),
        ("fedprox", FLConfig(algorithm="fedprox", local_steps=4,
                             local_lr=0.2, fedprox_mu=0.1)),
        ("scaffold", FLConfig(algorithm="scaffold", local_steps=4,
                              local_lr=0.2)),
        ("fedavg_iid", FLConfig(algorithm="fedavg", local_steps=4,
                                local_lr=0.2)),
    ]:
        het = 0.0 if name.endswith("iid") else 2.5
        losses, _, us = _fl_run(fl, rounds, het=het)
        res[name] = losses
        emit(f"convergence/{name}", us, het=het,
             loss_r5=round(losses[min(4, len(losses) - 1)], 4),
             loss_final=round(losses[-1], 4))
    emit("convergence/noniid_vs_iid_fedavg", 0.0,
         iid=round(res["fedavg_iid"][-1], 4),
         noniid=round(res["fedavg"][-1], 4),
         note="absolute-losses-not-comparable(entropy-differs-by-het)")

    # [46]'s drift construction: heterogeneous quadratics, E=10 local steps.
    # FedAvg converges to a biased point; SCAFFOLD to the true optimum.
    from repro.core.federated import _client_update
    d, C = 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    Q = jax.random.normal(ks[0], (C, d, d))
    A = jnp.einsum("cij,ckj->cik", Q, Q) / d + 0.1 * jnp.eye(d)
    b = jax.random.normal(ks[1], (C, d)) * 3.0
    wstar = jnp.linalg.solve(A.sum(0), jnp.einsum("cij,cj->i", A, b))

    class QuadModel:
        def loss(self, p, batch, chunk=0):
            r = p["w"] - batch["b"]
            return 0.5 * r @ batch["A"] @ r, {}

    def run(algo, E=10, lr=0.05, R=60):
        fl = FLConfig(algorithm=algo, local_steps=E, local_lr=lr)
        params, c = {"w": jnp.zeros(d)}, {"w": jnp.zeros(d)}
        ci = {"w": jnp.zeros((C, d))}
        step = jax.jit(lambda params, c, ci: jax.vmap(
            lambda bA, bb, cci: _client_update(
                QuadModel(), fl, params, {"A": bA, "b": bb},
                jax.random.PRNGKey(0), c, {"w": cci}, 0))(A, b, ci["w"]))
        for _ in range(R):
            deltas, _, _, new_ci = step(params, c, ci)
            params = jax.tree.map(lambda p, g: p + g.mean(0), params, deltas)
            if algo == "scaffold":
                c = jax.tree.map(lambda cc, n, o: cc + (n - o).mean(0),
                                 c, new_ci, ci)
                ci = new_ci
        return float(jnp.linalg.norm(params["w"] - wstar))

    e_avg, e_scaf = run("fedavg"), run("scaffold")
    emit("convergence/claim_scaffold_fixes_drift_quadratic", 0.0,
         holds=bool(e_scaf < 0.01 * e_avg),
         fedavg_bias=round(e_avg, 5), scaffold_err=round(e_scaf, 6))


def bench_bytes_to_loss(rounds):
    """The survey's central trade-off: accuracy vs communication bytes."""
    runs = {}
    for name, fl in [
        ("dense_f32", FLConfig(algorithm="fedavg", local_steps=2,
                               local_lr=0.2)),
        ("qsgd8+lfl", FLConfig(algorithm="fedavg", local_steps=2,
                               local_lr=0.2, uplink_compressor="qsgd8",
                               downlink_compressor="lfl8")),
        ("qsgd4", FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                           uplink_compressor="qsgd4")),
        # STC [39] compresses BOTH directions ("upstream and downstream")
        ("stc_1pct", FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                              uplink_compressor="stc", topk_fraction=0.01,
                              downlink_compressor="lfl8")),
        ("topk_1pct", FLConfig(algorithm="fedavg", local_steps=2,
                               local_lr=0.2, uplink_compressor="topk",
                               topk_fraction=0.01)),
        # combined scheme via the CommPipeline spec grammar: quantised-sparse
        ("topk5pct>>qsgd8", FLConfig(algorithm="fedavg", local_steps=2,
                                     local_lr=0.2,
                                     uplink_compressor="topk:0.05>>qsgd:8")),
        # DGC: momentum-corrected sparsification
        ("dgc_1pct", FLConfig(algorithm="fedavg", local_steps=2,
                              local_lr=0.2, uplink_compressor="topk",
                              topk_fraction=0.01, dgc_momentum=0.9)),
        ("sketch", FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.1,
                            uplink_compressor="sketch",
                            topk_fraction=0.1)),
    ]:
        losses, bytes_cum, us = _fl_run(fl, rounds)
        runs[name] = (losses, bytes_cum)
        emit(f"bytes_to_loss/{name}", us,
             loss_final=round(losses[-1], 4),
             mb_total=round(bytes_cum[-1] / 1e6, 2))
    _emit_bytes_to_target("bytes_to_loss", runs)


def bench_combined(rounds):
    """Combined-scheme sweep over the CommPipeline spec grammar: a topk
    fraction x qsgd bits grid plus sketch>>qsgd, reporting bytes to reach a
    common target loss — the per-arch Pareto points read off these rows."""
    base = dict(algorithm="fedavg", local_steps=2, local_lr=0.2)
    configs = [("dense_f32", FLConfig(**base))]
    for frac in (0.01, 0.05, 0.25):
        for bits in (4, 8):
            spec = f"topk:{frac:g}>>qsgd:{bits}"
            configs.append((spec.replace(":", "").replace(">>", "+"),
                            FLConfig(uplink_compressor=spec, **base)))
    configs.append(("sketch+qsgd8",
                    FLConfig(uplink_compressor="sketch>>qsgd:8",
                             **{**base, "local_lr": 0.1})))
    runs = {}
    for name, fl in configs:
        losses, bytes_cum, us = _fl_run(fl, rounds)
        runs[name] = (losses, bytes_cum)
        emit(f"combined/{name}", us, loss_final=round(losses[-1], 4),
             mb_total=round(bytes_cum[-1] / 1e6, 2))
    _emit_bytes_to_target("combined", runs)


def bench_async(rounds):
    """Stragglers, not bytes, dominate once the wire is compressed: under a
    heavy-tailed device-latency profile a synchronous round costs the MAX of
    the per-client latency draws, while the AsyncEngine's buffered server
    progresses on the fast clients.  Emits loss-vs-virtual-time and
    loss-vs-bytes for sync FedAvg vs FedBuff(K) vs FedAsync(K=1) vs
    deadline-flush FedBuff (adaptive buffer sizing, DESIGN.md §8) on the
    identical workload, plus the time-to-target claim rows (promoted to
    EXPERIMENTS.md §Async)."""
    from repro.core.async_engine import make_async_step
    from repro.data.pipeline import device_latency

    clients, profile = 8, "heavy_tail"
    base = dict(algorithm="fedavg", local_steps=2, local_lr=0.2,
                uplink_compressor="qsgd8")
    cfg = get_arch("paper_lm")
    model = Model(cfg)
    dcfg = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=clients,
                         seq_len=48, batch_per_client=4, heterogeneity=2.0,
                         seed=0)
    ev = eval_batch(dcfg, jax.random.PRNGKey(99), batch_size=8)

    def data_fn(r):
        return sample_round(dcfg, jax.random.fold_in(jax.random.PRNGKey(1), r))

    def metrics_fn(state, m):
        return dict(m, eval_loss=model.loss(state.params, ev, chunk=48)[0])

    # --- sync baseline: barrier per round => round time = max(latencies) ---
    losses, bytes_cum, us = _fl_run(FLConfig(**base), rounds)
    resources = sample_round(dcfg, jax.random.PRNGKey(7))["resources"]
    t, sync_t = 0.0, []
    for r in range(rounds):
        lat = device_latency(profile, resources,
                             jax.random.fold_in(jax.random.PRNGKey(13), r))
        t += float(jnp.max(lat))
        sync_t.append(t)
    runs = {"sync_fedavg": (losses, bytes_cum, sync_t)}
    emit("async/sync_fedavg", us, loss_final=round(losses[-1], 4),
         mb=round(bytes_cum[-1] / 1e6, 2), vclock=round(sync_t[-1], 1))

    # --- async runs: same upload budget (rounds*C events) ------------------
    # deadline-flush (adaptive buffer sizing, DESIGN.md §8): K = C never
    # fills before the stragglers land, so flush cadence is purely
    # time-driven — the deadline is the median fault-free device latency
    # (the server waits one "typical" client, never a Pareto tail draw)
    dl = float(np.median(np.asarray(
        device_latency("resource", resources, jax.random.PRNGKey(0)))))
    n_events = rounds * clients
    for name, K, deadline in [("fedbuff_k4", 4, None),
                              ("fedbuff_k2", 2, None),
                              ("fedasync_k1", 1, None),
                              ("fedbuff_deadline", clients, dl)]:
        fl = FLConfig(**base)
        a = make_async_step(model, fl, clients, data_fn, buffer_size=K,
                            staleness_alpha=0.5, latency_profile=profile,
                            flush_deadline=deadline, chunk=48)
        state = a.init_fn(jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        state, ms = run_rounds(a.engine, state, data_fn, n_events, chunk=16,
                               metrics_fn=metrics_fn, eval_every=clients)
        jax.block_until_ready(ms["clock"])
        us = (time.perf_counter() - t0) / n_events * 1e6
        evl = np.asarray(ms["eval_loss"], np.float64)
        clock = np.asarray(ms["clock"], np.float64)
        per_event = (np.asarray(ms["ledger"].uplink_wire, np.float64)
                     + np.asarray(ms["ledger"].downlink_wire, np.float64))
        cum = np.cumsum(per_event)
        keep = np.isfinite(evl)                  # eval cadence: every C events
        runs[name] = (list(evl[keep]), list(cum[keep]), list(clock[keep]))
        emit(f"async/{name}", us, loss_final=round(evl[keep][-1], 4),
             mb=round(cum[-1] / 1e6, 2), vclock=round(clock[-1], 1),
             mean_staleness=round(float(np.asarray(ms["staleness"]).mean()), 2),
             versions=int(np.asarray(ms["server_version"])[-1]))

    # --- time-to-target + bytes-to-target on the shared loss target --------
    # the target is pinned to the pre-existing claim runs (sync + the
    # count-flush family): adding new variants to the sweep must not
    # re-base the loss bar the established sync-vs-FedBuff claim is
    # measured against (new variants are judged on the same bar)
    claim_runs = ("sync_fedavg", "fedbuff_k4", "fedbuff_k2", "fedasync_k1")
    target = max(runs[n][0][-1] for n in claim_runs) + 0.02
    tt = {}
    for name, (l, b, vt) in runs.items():
        idx = next((i for i, x in enumerate(l) if x <= target), None)
        tt[name] = (vt[idx] if idx is not None else float("inf"),
                    b[idx] / 1e6 if idx is not None else float("inf"))
        emit(f"async/target/{name}", 0.0, target=round(target, 3),
             vclock_to_target=round(tt[name][0], 1),
             mb_to_target=round(tt[name][1], 2))
    best_buff = min(tt["fedbuff_k4"][0], tt["fedbuff_k2"][0])
    emit("async/claim_fedbuff_beats_sync_time_to_target", 0.0,
         holds=bool(best_buff < tt["sync_fedavg"][0]),
         fedbuff_vclock=round(best_buff, 1),
         sync_vclock=round(tt["sync_fedavg"][0], 1),
         note="heavy-tail-stragglers-paper_lm")
    # adaptive buffer sizing: deadline-flush vs the best count-flush K —
    # under heavy tails the deadline caps how long the buffer waits on a
    # Pareto draw, so its time-to-target should at least match K-flush
    emit("async/claim_deadline_flush_vs_k_flush", 0.0,
         holds=bool(np.isfinite(tt["fedbuff_deadline"][0])
                    and tt["fedbuff_deadline"][0] <= 1.25 * best_buff),
         deadline_vclock=round(tt["fedbuff_deadline"][0], 1),
         k_flush_vclock=round(best_buff, 1),
         deadline=round(dl, 2),
         note="heavy-tail-stragglers-paper_lm")


def bench_scale(rounds):
    """ClientPopulation scale claim (DESIGN.md §9): 100k and 1M simulated
    clients train paper_lm with per-client pipeline state bounded by the
    residual-store capacity — memory flat in population size.  Also emits
    the degenerate bit-exactness claim (capacity >= C, cohort = C ==> the
    population path reproduces the dense sim/async engines bit-for-bit)
    and the EF-convergence cost of the eviction policy (full store vs
    evict-to-drop vs evict-to-sketch at the same cohort)."""
    from repro.compress.residual_store import store_nbytes
    from repro.core.engine import Topology, make_round_engine
    from repro.core.population import ClientPopulation
    from repro.data.pipeline import cohort_data_fn

    cfg = get_arch("paper_lm")
    model = Model(cfg)
    base = dict(algorithm="fedavg", local_steps=2, local_lr=0.2,
                uplink_compressor="topk:0.05>>qsgd:8")
    cohort, capacity = 16, 64

    # --- memory flat in population size ------------------------------------
    pops = [100_000] if SMOKE else [100_000, 1_000_000]
    n_rounds = 2 if SMOKE else max(4, min(rounds, 8))
    store_b = {}
    for N in pops:
        pop = ClientPopulation(n_clients=N, cohort=cohort, capacity=capacity,
                               sampler="stride")
        dcfg = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=N,
                             seq_len=48, batch_per_client=4,
                             heterogeneity=2.0)
        data_fn = cohort_data_fn(pop, dcfg)
        engine = make_round_engine(model, FLConfig(**base), Topology.sim(N),
                                   chunk=48, population=pop)
        state = engine.init_fn(jax.random.PRNGKey(0))
        store_b[N] = store_nbytes(state.comm_state)
        t0 = time.perf_counter()
        state, ms = run_rounds(engine, state, data_fn, n_rounds, chunk=2)
        jax.block_until_ready(ms["loss"])
        us = (time.perf_counter() - t0) / n_rounds * 1e6
        emit(f"scale/population_{N}", us,
             loss_final=round(float(ms["loss"][-1]), 4),
             store_mb=round(store_b[N] / 1e6, 3),
             cohort=cohort, capacity=capacity, sampler="stride")
    emit("scale/claim_memory_flat_in_population", 0.0,
         holds=bool(len(set(store_b.values())) == 1),
         store_mb=round(max(store_b.values()) / 1e6, 3),
         populations="|".join(str(n) for n in store_b),
         note="store-bytes-bounded-by-capacity-not-C")

    # --- async leg: the same store drives the event engine -----------------
    N = pops[0]
    pop = ClientPopulation(n_clients=N, cohort=cohort, capacity=capacity,
                           sampler="stride")
    dcfg = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=N,
                         seq_len=48, batch_per_client=4, heterogeneity=2.0)
    data_fn = cohort_data_fn(pop, dcfg)
    engine = make_round_engine(
        model, FLConfig(latency_profile="heavy_tail", **base),
        Topology.async_(N, buffer_size=max(2, cohort // 4)),
        chunk=48, data_fn=data_fn, population=pop)
    state = engine.init_fn(jax.random.PRNGKey(0))
    n_events = n_rounds * cohort
    t0 = time.perf_counter()
    state, ms = run_rounds(engine, state, data_fn, n_events, chunk=8)
    jax.block_until_ready(ms["loss"])
    us = (time.perf_counter() - t0) / n_events * 1e6
    emit(f"scale/async_population_{N}", us,
         loss_final=round(float(ms["loss"][-1]), 4),
         store_mb=round(store_nbytes(state.comm_state) / 1e6, 3),
         vclock=round(float(ms["clock"][-1]), 1),
         versions=int(np.asarray(ms["server_version"])[-1]))

    # --- degenerate bit-exactness: capacity >= C, cohort = C ---------------
    def _bitexact(async_mode):
        C, R = 4, 3
        fl = FLConfig(uplink_compressor="topk:0.25>>qsgd:8",
                      **({"latency_profile": "constant"} if async_mode
                         else {}), algorithm="fedavg", local_steps=2,
                      local_lr=0.2)
        dc_ = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=C,
                            seq_len=32, batch_per_client=2,
                            heterogeneity=1.5)
        dfn = lambda r: sample_round(dc_, jax.random.fold_in(
            jax.random.PRNGKey(1), r))
        topo = (Topology.async_(C, buffer_size=C,
                                latency_profile="constant")
                if async_mode else Topology.sim(C))
        outs = []
        for pop_ in (None, ClientPopulation(n_clients=C, cohort=C,
                                            capacity=C)):
            e = make_round_engine(model, fl, topo, chunk=32, data_fn=dfn,
                                  population=pop_)
            st = e.init_fn(jax.random.PRNGKey(0))
            st, _ = run_rounds(e, st, dfn, R * C if async_mode else R,
                               chunk=4, donate=False)
            comm = (st.comm_state["slab"] if isinstance(st.comm_state, dict)
                    else st.comm_state)
            outs.append((st.params, comm))
        return all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(outs[0]),
                            jax.tree.leaves(outs[1])))
    emit("scale/claim_degenerate_bitexact", 0.0,
         holds=bool(_bitexact(False) and _bitexact(True)),
         note="params-and-comm_state-sync-and-async-capacity>=C")

    # --- EF-convergence cost of the eviction policy ------------------------
    N2, M2, R2 = 192, 24, (4 if SMOKE else max(10, min(rounds, 30)))
    dcfg2 = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=N2,
                          seq_len=48, batch_per_client=4, heterogeneity=2.0)
    ev = eval_batch(FedDataConfig(vocab_size=cfg.vocab_size, num_clients=8,
                                  seq_len=48, batch_per_client=4,
                                  heterogeneity=2.0),
                    jax.random.PRNGKey(99), batch_size=8)
    for name, cap, policy in [("full_store", N2, "drop"),
                              ("evict_drop", 32, "drop"),
                              ("evict_sketch", 32, "sketch")]:
        pop_ = ClientPopulation(n_clients=N2, cohort=M2, capacity=cap,
                                eviction=policy)
        dfn = cohort_data_fn(pop_, dcfg2)
        e = make_round_engine(model, FLConfig(**base), Topology.sim(N2),
                              chunk=48, population=pop_)
        st = e.init_fn(jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        st, ms = run_rounds(e, st, dfn, R2, chunk=4)
        jax.block_until_ready(ms["loss"])
        us = (time.perf_counter() - t0) / R2 * 1e6
        ev_loss = float(model.loss(st.params, ev, chunk=48)[0])
        emit(f"scale/eviction_{name}", us, eval_loss=round(ev_loss, 4),
             loss_final=round(float(ms["loss"][-1]), 4),
             capacity=cap, cohort=M2, population=N2,
             store_mb=round(store_nbytes(st.comm_state) / 1e6, 3))


def bench_engine(rounds):
    """RoundEngine acceptance row: run_rounds (scan, chunk=8) vs the Python
    round loop over the jit'd step — identical final params for fixed seed,
    wall-clock per round for both drivers (compile excluded)."""
    fl = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                  uplink_compressor="qsgd8")
    cfg = get_arch("paper_lm")
    model = Model(cfg)
    clients, rounds = 8, max(8, rounds)
    dcfg = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=clients,
                         seq_len=48, batch_per_client=4, heterogeneity=2.0)
    sim = make_sim_step(model, fl, clients, chunk=48)

    def data_fn(r):
        return sample_round(dcfg, jax.random.fold_in(jax.random.PRNGKey(1), r))

    # --- Python round loop over the jit'd step ----------------------------
    state = sim.init_fn(jax.random.PRNGKey(0))
    state, _ = sim.step_fn(state, data_fn(jnp.int32(0)))     # compile
    state = sim.init_fn(jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    for r in range(rounds):
        state, m = sim.step_fn(state, data_fn(jnp.int32(r)))
    jax.block_until_ready(state.params)
    loop_us = (time.perf_counter() - t0) / rounds * 1e6
    loop_params = state.params

    # --- scan driver ------------------------------------------------------
    from repro.core.engine import RoundRunner
    runner = RoundRunner(sim.engine, data_fn, chunk=8)
    s2, _ = runner.run(sim.init_fn(jax.random.PRNGKey(0)), rounds)  # compile
    t0 = time.perf_counter()
    s2, ms = runner.run(sim.init_fn(jax.random.PRNGKey(0)), rounds)
    jax.block_until_ready(s2.params)
    scan_us = (time.perf_counter() - t0) / rounds * 1e6

    diff = max(float(jnp.abs(a - b).max()) for a, b in
               zip(jax.tree.leaves(loop_params), jax.tree.leaves(s2.params)))
    emit("engine/scan_vs_loop", scan_us, loop_us=round(loop_us, 1),
         speedup=round(loop_us / scan_us, 3), rounds=rounds,
         max_param_diff=diff, identical=bool(diff == 0.0))


def bench_selection(rounds):
    res = {}
    for name, fl in [
        ("all", FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2)),
        ("random_4of16", FLConfig(algorithm="fedavg", local_steps=2,
                                  local_lr=0.2, selection="random",
                                  clients_per_round=4)),
        ("power_of_choice_4of16", FLConfig(algorithm="fedavg", local_steps=2,
                                           local_lr=0.2,
                                           selection="power_of_choice",
                                           clients_per_round=4)),
        ("multi_criteria_4of16", FLConfig(algorithm="fedavg", local_steps=2,
                                          local_lr=0.2,
                                          selection="multi_criteria",
                                          clients_per_round=4)),
    ]:
        losses, bytes_cum, us = _fl_run(fl, rounds, clients=16)
        res[name] = losses
        emit(f"selection/{name}", us, loss_final=round(losses[-1], 4),
             mb=round(bytes_cum[-1] / 1e6, 2))
    # the claim is about expected behaviour — average over seeds (a single
    # 30-round run sits within seed noise)
    pocs, rands = [res["power_of_choice_4of16"][-1]], [res["random_4of16"][-1]]
    for seed in (1, 2):
        fl_p = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                        selection="power_of_choice", clients_per_round=4)
        fl_r = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                        selection="random", clients_per_round=4)
        pocs.append(_fl_run(fl_p, rounds, clients=16, seed=seed)[0][-1])
        rands.append(_fl_run(fl_r, rounds, clients=16, seed=seed)[0][-1])
    poc_m, rand_m = float(np.mean(pocs)), float(np.mean(rands))
    emit("selection/claim_poc_beats_random", 0.0,
         holds=bool(poc_m <= rand_m + 0.02), seeds=len(pocs),
         poc_mean=round(poc_m, 4), rand_mean=round(rand_m, 4))


def bench_hierarchy(rounds):
    """Cost model for Hier-Local-QSGD / FedPAQ periodic averaging: cloud (DCN)
    bytes drop by ~sync_every; edge (ICI) traffic unchanged."""
    from repro.core.federated import ledger_terms
    cfg = get_arch("paper_lm")
    model = Model(cfg)
    n = model.param_count()
    for sync_every in (1, 2, 4, 8):
        fl = FLConfig(hierarchical=True, sync_every=sync_every,
                      uplink_compressor="qsgd8", pod_compressor="qsgd8")
        _, up, _ = ledger_terms(model, fl)
        edge = 16 * up.wire_bits(n) / 8e6          # 16 clients/pod, per round
        cloud = 2 * up.wire_bits(n) / 8e6 / sync_every  # 2 pods, amortised
        emit(f"hierarchy/sync_every_{sync_every}", 0.0,
             edge_mb_per_round=round(edge, 3),
             cloud_mb_per_round=round(cloud, 3),
             dcn_saving=round(float(sync_every), 1))


def bench_extensions(rounds):
    """FedDANE [49], CMFL [35], FL+HC [43] — §III.B.1/.3 completions."""
    import numpy as _np
    from repro.core.clustering import (adjusted_match, agglomerate,
                                       pairwise_delta_distance)
    from repro.core.federated import _client_update
    from repro.data.synthetic import client_clusters

    # FedDANE converges on the LM task at 2x wire per round
    fl = FLConfig(algorithm="feddane", local_steps=4, local_lr=0.1,
                  fedprox_mu=0.01)
    losses, bytes_cum, us = _fl_run(fl, max(8, rounds // 3))
    emit("extensions/feddane", us, loss_final=round(losses[-1], 4),
         mb=round(bytes_cum[-1] / 1e6, 2), wire_factor=2.0)

    # CMFL: relevance filtering cuts uploads at comparable loss
    base = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2)
    filt = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                    cmfl_threshold=0.5)
    lb, bb, _ = _fl_run(base, rounds)
    lf, bf, us = _fl_run(filt, rounds)
    emit("extensions/cmfl", us,
         loss_base=round(lb[-1], 4), loss_cmfl=round(lf[-1], 4),
         mb_base=round(bb[-1] / 1e6, 2), mb_cmfl=round(bf[-1] / 1e6, 2),
         upload_saving=round(bb[-1] / max(bf[-1], 1.0), 2),
         note="sign-agreement-concentrates-near-0.5-so-threshold-is-sharp")

    # FL+HC: update-similarity clustering recovers the generator clusters
    cfg = get_arch("paper_lm")
    model = Model(cfg)
    C = 8
    dcfg = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=C,
                         seq_len=32, batch_per_client=4, heterogeneity=6.0,
                         client_skew=0.0, num_clusters=2, seed=3)
    flh = FLConfig(algorithm="fedavg", local_steps=4, local_lr=0.3)
    params = model.init(jax.random.PRNGKey(0))
    deltas = None
    for r in range(3):
        b = sample_round(dcfg, jax.random.fold_in(jax.random.PRNGKey(4), r))
        deltas, _, _, _ = jax.vmap(lambda tok, lab, msk: _client_update(
            model, flh, params, {"tokens": tok, "labels": lab, "mask": msk},
            jax.random.PRNGKey(0), None, None, 32))(
            b["tokens"], b["labels"], b["mask"])
        params = jax.tree.map(
            lambda p, d: (p + d.mean(0)).astype(p.dtype), params, deltas)
    flat = _np.concatenate([_np.asarray(l.reshape(C, -1), _np.float32)
                            for l in jax.tree.leaves(deltas)], axis=1)
    D = pairwise_delta_distance(flat, "cosine")
    labels = agglomerate(D, threshold=float(_np.median(D)))
    score = adjusted_match(labels, _np.asarray(client_clusters(dcfg)))
    emit("extensions/flhc_cluster_recovery", 0.0,
         pairwise_match=round(score, 3), holds=bool(score >= 0.7))


def bench_roofline(rounds):
    import sys
    sys.path.insert(0, os.path.dirname(__file__))
    from roofline_report import load
    base = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun")
    recs = load("pod1", "baseline", base)
    if not recs:
        emit("roofline/missing", 0.0,
             note="run repro.launch.dryrun first")
        return
    for (arch, shape), r in sorted(recs.items()):
        if not r.get("ok"):
            emit(f"roofline/{arch}/{shape}", 0.0, ok=False)
            continue
        t = r["roofline"]
        emit(f"roofline/{arch}/{shape}", r["total_s"] * 1e6,
             compute_s=round(t["compute_s"], 3),
             memory_s=round(t["memory_s"], 3),
             collective_s=round(t["collective_s"], 3),
             dominant=r["dominant"],
             useful_flops=round(r["useful_flops_ratio"], 3))


def bench_fused(rounds):
    """DESIGN.md §10 — the packed-wire claim on paper_lm, measured on the
    compiled star-topology program (8 host devices, client axis = data):

      * HLO-verified collective bytes: the all-gather operand IS the packed
        payload, so the gathered u8 code plane equals the ledger's packed
        code bytes EXACTLY (claim_ledger_eq_hlo) and total all-gather bytes
        strictly shrink vs the staged wire (claim_packed_shrinks_wire);
      * encode wall-clock: fusing the bitpack into the encode costs nothing
        in aggregate vs the staged path (claim_encode_no_worse) — also the
        regression guard for the top_k TopkRewriter trap (a scalar slice
        fused into top_k's output reverts XLA to a full sort);
      * HBM per round via XLA cost analysis (informational rows).
    """
    import re
    from repro.compress.wire_format import payload_nbytes
    from repro.core.compat import make_mesh
    from repro.core.federated import make_fl_train_step
    from repro.launch import hlo_analysis

    cfg = get_arch("paper_lm")
    model = Model(cfg)
    sizes = [int(np.prod(l.shape))
             for l in jax.tree.leaves(model.abstract_params())]
    specs = ["ternary", "stc:0.1", "topk:0.05>>qsgd:4"]

    # --- encode wall-clock: staged vs packed on the largest leaf ----------
    n = max(sizes)
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    reps = 5 if SMOKE else 10
    tot_stg, tot_pkd = 0.0, 0.0
    for spec in specs:
        stg = make_compressor(spec)
        pkd = make_compressor(spec, wire_format="packed")
        us_s = _timeit(jax.jit(
            lambda r, v, p=stg: p.encode(p.init((n,)), r, v)[0]),
            jax.random.PRNGKey(1), x, reps=reps)
        us_p = _timeit(jax.jit(
            lambda r, v, p=pkd: p.encode(p.init((n,)), r, v)[0]),
            jax.random.PRNGKey(1), x, reps=reps)
        tot_stg, tot_pkd = tot_stg + us_s, tot_pkd + us_p
        emit(f"fused/encode/{spec}", us_p, staged_us=round(us_s, 1),
             ratio=round(us_p / us_s, 3), n=n)
    # aggregate over the three specs with a CPU-timer noise margin; the
    # real guard is against the ~4.5x TopkRewriter fallback class of
    # regression, not single-digit-percent jitter — smoke's 5-rep timings
    # on a loaded CI runner swing past 10%, so smoke only screens for the
    # regression class and the full run enforces the tight bound
    margin = 2.0 if SMOKE else 1.10
    emit("fused/claim_encode_no_worse", tot_pkd,
         staged_us=round(tot_stg, 1), ratio=round(tot_pkd / tot_stg, 3),
         holds=bool(tot_pkd <= margin * tot_stg))

    # --- HLO collective bytes on the compiled star program ----------------
    if jax.device_count() < 8:
        emit("fused/hlo", 0.0, note="needs 8 devices (run --only fused; "
             "the argv guard sets XLA_FLAGS before jax import)")
        return
    # model axis of size 1: every all-gather in the program is the client
    # aggregation wire, so total-AG comparisons are pure payload
    mesh = make_mesh((8, 1), ("data", "model"))

    def ag_bytes_by_dtype(hlo_text):
        """Sum all-gather result bytes per dtype (variadic AGs included)."""
        isize = {"pred": 1, "u8": 1, "s8": 1, "u16": 2, "s16": 2, "bf16": 2,
                 "f16": 2, "u32": 4, "s32": 4, "f32": 4, "u64": 8, "s64": 8,
                 "f64": 8}
        out = {}
        for line in hlo_text.splitlines():
            if "all-gather(" not in line:
                continue
            head = line.split("all-gather(", 1)[0]
            for dt, dims in re.findall(r"(\w+)\[([\d,]*)\]", head):
                if dt not in isize:
                    continue
                count = int(np.prod([int(d) for d in dims.split(",") if d]
                                    or [1]))
                out[dt] = out.get(dt, 0) + count * isize[dt]
        return out

    def compile_step(spec, wire):
        fl = FLConfig(algorithm="fedsgd", uplink_compressor=spec,
                      wire_format=wire)
        step = make_fl_train_step(model, fl, mesh, chunk=32)
        state = jax.eval_shape(step.init_fn,
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        C, B, S = step.n_clients, 2, 32
        key = jax.random.PRNGKey(1)
        t = jax.random.randint(key, (C, B, S), 0, cfg.vocab_size)
        batch = {"tokens": t, "labels": t, "mask": jnp.ones((C, B, S)),
                 "sizes": jnp.ones((C,)),
                 "resources": jax.random.uniform(key, (C, 4))}
        abstract = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                    for k, v in batch.items()}
        fn = jax.jit(step.step_fn,
                     in_shardings=(step.state_shardings,
                                   step.batch_sharding_fn(abstract)))
        return fn.lower(state, abstract).compile(), step.n_clients

    def code_plane_bytes(pipe, C):
        """Ledger's packed/staged code bytes: int-dtype payload leaves,
        summed over model leaves, x C clients gathered."""
        total = {}
        for m in sizes:
            state = jax.eval_shape(lambda m=m: pipe.init((m,)))
            payload, _ = jax.eval_shape(
                pipe.encode, state, jax.ShapeDtypeStruct((2,), jnp.uint32),
                jax.ShapeDtypeStruct((m,), jnp.float32))
            for l in jax.tree.leaves(payload):
                dt = jnp.dtype(l.dtype).name
                total[dt] = total.get(dt, 0) + \
                    int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
        return {k: C * v for k, v in total.items()}

    dt_map = {"uint8": "u8", "int8": "s8", "int32": "s32", "float32": "f32"}
    for spec in specs:
        comp_s, C = compile_step(spec, "staged")
        comp_p, _ = compile_step(spec, "packed")
        ag_s = ag_bytes_by_dtype(comp_s.as_text())
        ag_p = ag_bytes_by_dtype(comp_p.as_text())
        pipe_p = make_compressor(spec, wire_format="packed")
        pipe_s = make_compressor(spec)
        led_p = code_plane_bytes(pipe_p, C)
        led_s = code_plane_bytes(pipe_s, C)
        # the packed u8 code plane crosses the wire exactly as ledgered
        # (the f32 side info — mu/scales — is byte-equal too, verified at
        # the payload level by tests/test_kernel_parity.py)
        eq = ag_p.get("u8", 0) == led_p.get("uint8", -1)
        # staged control: its s8 plane is ledger-exact as well
        eq_s = ag_s.get("s8", 0) == led_s.get("int8", -1)
        ledger_total_p = C * sum(payload_nbytes(pipe_p, m) for m in sizes)
        ledger_total_s = C * sum(payload_nbytes(pipe_s, m) for m in sizes)
        st_s = hlo_analysis.analyze(comp_s.as_text())
        st_p = hlo_analysis.analyze(comp_p.as_text())
        try:
            hbm_s = float(comp_s.cost_analysis()["bytes accessed"])
            hbm_p = float(comp_p.cost_analysis()["bytes accessed"])
        except Exception:
            hbm_s, hbm_p = st_s.hbm_bytes, st_p.hbm_bytes
        tot_s = sum(ag_s.values())
        tot_p = sum(ag_p.values())
        emit(f"fused/wire/{spec}", 0.0,
             ag_mb_staged=round(tot_s / 1e6, 4),
             ag_mb_packed=round(tot_p / 1e6, 4),
             ledger_mb_staged=round(ledger_total_s / 1e6, 4),
             ledger_mb_packed=round(ledger_total_p / 1e6, 4),
             ag_by_dtype_packed=str(ag_p).replace(",", "|"),
             hbm_mb_staged=round(hbm_s / 1e6, 1),
             hbm_mb_packed=round(hbm_p / 1e6, 1))
        extra = {}
        if not (eq and eq_s):
            # flight-recorder cross-check (repro.obs + launch.hlo_analysis):
            # decompose the billed bytes per pipeline stage and name the
            # stage whose share best explains the HLO/ledger gap
            from repro.obs.telemetry import telemetry_spec
            spec_tel = telemetry_spec(pipe_p, None, sizes, up_scale=float(C))
            msg = hlo_analysis.name_stage_mismatch(
                spec_tel.up_names, spec_tel.up_table,
                measured=float(sum(ag_p.values())),
                expected_total=float(ledger_total_p))
            extra["stage_hint"] = msg.replace(",", ";") or "none"
        emit(f"fused/claim_ledger_eq_hlo/{spec}", 0.0,
             hlo_u8=ag_p.get("u8", 0), ledger_u8=led_p.get("uint8", -1),
             staged_s8_eq=eq_s, holds=bool(eq and eq_s), **extra)
        emit(f"fused/claim_packed_shrinks_wire/{spec}", 0.0,
             reduction=round(tot_s / max(tot_p, 1), 3),
             holds=bool(tot_p < tot_s))


def _privacy_run(fl: FLConfig, rounds, seed=0):
    """Like ``_fl_run`` but returns the final state and raw metrics so the
    privacy bench can compare params / comm_state / ledger bitwise."""
    cfg = get_arch("paper_lm")
    model = Model(cfg)
    dcfg = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=8,
                         seq_len=48, batch_per_client=4, heterogeneity=2.0,
                         seed=seed)
    sim = make_sim_step(model, fl, 8, chunk=48)
    state = sim.init_fn(jax.random.PRNGKey(seed))
    ev = eval_batch(dcfg, jax.random.PRNGKey(99), batch_size=8)

    def data_fn(r):
        return sample_round(dcfg, jax.random.fold_in(
            jax.random.PRNGKey(seed + 1), r))

    def metrics_fn(state, m):
        return dict(m, eval_loss=model.loss(state.params, ev, chunk=48)[0])

    t0 = time.perf_counter()
    state, ms = run_rounds(sim.engine, state, data_fn, rounds, chunk=4,
                           metrics_fn=metrics_fn, donate=False)
    jax.block_until_ready(ms)
    us = (time.perf_counter() - t0) / rounds * 1e6
    return state, ms, us


def bench_privacy(rounds):
    """DESIGN.md §11 — the privacy-compatible wire stack, two claims and a
    Pareto sweep:

      * masking is FREE in fidelity and on the wire: a secagg run equals
        the clear run bitwise (params, ctx-stripped comm_state, billed
        wire bytes) because ring masks cancel in integer arithmetic —
        the differential the test harness (tests/test_secure_agg.py)
        proves per-topology, re-measured here on the benchmark workload;
      * DP noise traces the privacy/bytes/accuracy Pareto: sigma sweeps
        epsilon down at bit-identical wire cost, paying only in loss.
    """
    from repro.compress.secure_agg import drop_mask_ctx, zcdp_epsilon

    rounds = 4 if SMOKE else max(rounds, 10)
    base_spec = "topk:0.05>>qsgd:4"
    fl = dict(algorithm="fedavg", local_steps=2, local_lr=0.2)

    def leaves_equal(a, b):
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        return len(la) == len(lb) and all(
            bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))

    # --- claim 1: masked == unmasked, bitwise ------------------------------
    st_c, ms_c, us_c = _privacy_run(
        FLConfig(uplink_compressor=base_spec, **fl), rounds)
    st_m, ms_m, us_m = _privacy_run(
        FLConfig(uplink_compressor=base_spec + ">>secagg", **fl), rounds)
    wire_c = np.asarray(ms_c["ledger"].uplink_wire, np.float64)
    wire_m = np.asarray(ms_m["ledger"].uplink_wire, np.float64)
    params_eq = leaves_equal(st_c.params, st_m.params)
    comm_eq = leaves_equal(st_c.comm_state, drop_mask_ctx(st_m.comm_state))
    wire_eq = bool(np.array_equal(wire_c, wire_m))
    emit("privacy/clear", us_c, spec=base_spec,
         loss_final=round(float(ms_c["eval_loss"][-1]), 4),
         mb=round(wire_c.sum() / 1e6, 2))
    emit("privacy/masked", us_m, spec=base_spec + ">>secagg",
         loss_final=round(float(ms_m["eval_loss"][-1]), 4),
         mb=round(wire_m.sum() / 1e6, 2),
         overhead_us=round(us_m - us_c, 1))
    emit("privacy/claim_masked_bitexact", 0.0,
         holds=bool(params_eq and comm_eq and wire_eq),
         params_eq=params_eq, comm_eq=comm_eq, wire_eq=wire_eq,
         rounds=rounds, spec=base_spec + ">>secagg")

    # --- claim 2: masking costs zero billed wire bits ----------------------
    n = 1 << 16
    zero_cost = True
    for spec in (base_spec, "qsgd:4", "ternary@fused", "qsgd:2@fused"):
        clear = make_compressor(spec)
        masked = make_compressor(spec + ">>secagg")
        zero_cost &= masked.wire_bits(n) == clear.wire_bits(n)
    emit("privacy/claim_masking_zero_wire_cost", 0.0,
         holds=bool(zero_cost and wire_eq), specs=4,
         note="ledger-wire-bits-identical;ctx-rides-payload-not-wire")

    # --- claim 3: dpnoise Pareto (privacy vs bytes vs accuracy) ------------
    # The ledger's dp_rho is the COHORT-summed spend (n_sel clients x rho
    # per round); a client-level DP guarantee composes only over one
    # client's own participations, so divide by the cohort size (every
    # client participates every round here) before converting to the
    # per-client (eps, delta) the Pareto chart stands on.
    cohort = 8                      # _privacy_run: num_clients=8, all selected
    sweep = []
    for sigma in (0.0, 0.5, 1.0):
        if sigma == 0.0:
            ms, mb = ms_m, wire_m.sum()
            rho_client = 0.0
        else:
            spec = f"{base_spec}>>dpnoise:{sigma:g}>>secagg"
            _, ms, _ = _privacy_run(FLConfig(uplink_compressor=spec, **fl),
                                    rounds)
            mb = float(np.asarray(ms["ledger"].uplink_wire,
                                  np.float64).sum())
            rho_client = float(np.asarray(ms["ledger"].dp_rho,
                                          np.float64).sum()) / cohort
        eps = zcdp_epsilon(rho_client, 1e-5) if rho_client else float("inf")
        loss = float(ms["eval_loss"][-1])
        sweep.append((sigma, eps, mb, loss))
        emit(f"privacy/dp_sigma_{sigma:g}", 0.0, eps=round(eps, 2),
             rho=round(rho_client, 3), mb=round(mb / 1e6, 2),
             loss_final=round(loss, 4), delta=1e-5,
             scope="per-client-zCDP")
    eps_monotone = all(a[1] > b[1] for a, b in zip(sweep, sweep[1:]))
    bytes_flat = len({round(s[2], 6) for s in sweep}) == 1
    emit("privacy/claim_dp_pareto", 0.0,
         holds=bool(eps_monotone and bytes_flat and
                    all(np.isfinite(s[3]) for s in sweep)),
         eps_monotone=eps_monotone, bytes_flat=bytes_flat,
         sigmas="0|0.5|1", note="per-client-eps;loss-reported-not-gated")


def bench_obs(rounds):
    """DESIGN.md §12 — the flight recorder, two claims on paper_lm:

      * claim_stage_sum_exact — with FLConfig.telemetry on, the RoundStats
        per-stage byte slots reconstruct CommLedger.uplink_wire /
        downlink_wire bit-exactly in f32 (residual construction) and match
        the direct stage-table sum in f64;
      * claim_telemetry_overhead — a traced run (telemetry + JSONL flight
        recorder) costs <= 1.05x the untraced telemetry-off wall clock
        (smoke=False: wall-clock race, the full run enforces the bound);
        the trace must validate and the report must render.
    """
    import tempfile
    from repro.obs.report import render, summarize
    from repro.obs.trace import Tracer, validate_file

    r = 4 if SMOKE else max(8, rounds)
    base = dict(uplink_compressor="topk", topk_fraction=0.05,
                error_feedback=True, eval_every=2)
    cfg = get_arch("paper_lm")
    model = Model(cfg)
    dcfg = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=8,
                         seq_len=48, batch_per_client=4, heterogeneity=2.0)
    ev = eval_batch(dcfg, jax.random.PRNGKey(99), batch_size=8)

    def data_fn(rd):
        return sample_round(dcfg, jax.random.fold_in(
            jax.random.PRNGKey(1), rd))

    def metrics_fn(state, m):
        return dict(m, eval_loss=model.loss(state.params, ev, chunk=48)[0])

    def one(fl, tracer=None):
        sim = make_sim_step(model, fl, 8, chunk=48)
        state = sim.init_fn(jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        state, ms = run_rounds(sim.engine, state, data_fn, r, chunk=4,
                               metrics_fn=metrics_fn, tracer=tracer)
        jax.block_until_ready(ms)
        return sim, ms, time.perf_counter() - t0

    # --- stage-sum exactness (deterministic; smoke-checkable) -------------
    _, ms, _ = one(FLConfig(telemetry=True, **base))
    up = np.asarray(ms["round_stats"].up_stage_bytes)
    dn = np.asarray(ms["round_stats"].down_stage_bytes)
    uw = np.asarray(ms["ledger"].uplink_wire)
    dw = np.asarray(ms["ledger"].downlink_wire)

    def _residual_exact(slots, totals):
        ok = True
        for i in range(slots.shape[0]):
            partial = np.float32(0.0)
            for v in slots[i][:-1]:
                partial = np.float32(partial + np.float32(v))
            ok &= bool(slots[i][-1]
                       == np.float32(np.float32(totals[i]) - partial))
        return ok

    exact = _residual_exact(up, uw) and _residual_exact(dn, dw)
    close64 = (np.allclose(up.astype(np.float64).sum(1), uw, rtol=1e-6)
               and np.allclose(dn.astype(np.float64).sum(1), dw, rtol=1e-6))
    emit("obs/claim_stage_sum_exact", 0.0,
         holds=bool(exact and close64), rounds=r,
         f32_residual=exact, f64_close=close64,
         up_mb=round(float(uw.sum()) / 1e6, 4))

    # --- overhead: traced vs untraced (wall-clock; not smoke-checkable) ---
    # warm both paths, then INTERLEAVE off/on reps and take the min of each
    # side: machine-load drift on a shared runner is ~10% run-to-run, far
    # above the 5% bound, so timing all-off-then-all-on would let the
    # scheduler decide the claim.  Alternating pairs exposes both sides to
    # the same load profile; min-of-reps discards the blips.
    reps = 1 if SMOKE else 5
    one(FLConfig(**base))
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "bench_obs.jsonl")
        tracer = Tracer(path, meta=dict(arch="paper_lm", rounds=r))
        one(FLConfig(telemetry=True, **base), tracer=tracer)   # warm-up
        wall_off, wall_on, ms2, sim2 = np.inf, np.inf, None, None
        for _ in range(reps):
            wall_off = min(wall_off, one(FLConfig(**base))[2])
            sim2, ms2, w = one(FLConfig(telemetry=True, **base),
                               tracer=tracer)
            wall_on = min(wall_on, w)
        tracer.emit_rounds(ms2, spec=sim2.engine.aux.get("telemetry"))
        tracer.close()
        records = validate_file(path)
        report = render(summarize(records))
    margin = 2.0 if SMOKE else 1.05
    emit("obs/claim_telemetry_overhead", wall_on / r * 1e6,
         untraced_us=round(wall_off / r * 1e6, 1),
         ratio=round(wall_on / max(wall_off, 1e-9), 3),
         trace_records=len(records), report_lines=len(report.splitlines()),
         holds=bool(wall_on <= margin * wall_off
                    and len(records) > r and len(report) > 0))


def bench_scenario(rounds):
    """Client-dynamics scenario pack (core.scenario, DESIGN.md §13): the
    realistic-conditions re-measurement of the async headline claims.

    Three legs: (a) trace duty-cycle fidelity — the square/diurnal traces
    hit their configured duty exactly / in mean (deterministic, smoke-
    checkable); (b) adaptive deadline arming — the completion-time
    quantile tracker converges on the constant-latency profile
    (deterministic); (c) the sync-vs-FedBuff time-to-target race re-run
    under diurnal availability + mid-round dropout on the sync leg and
    dropout + adaptive deadline on the async leg (seed-pinned,
    smoke=False — nightly tier).  The dynamics are topology-honest:
    availability traces only exist on the synchronous selection hop (the
    async engine rejects them), so the race compares each topology under
    the dynamics it can express."""
    from repro.core import scenario as scn
    from repro.core.async_engine import make_async_step
    from repro.data.pipeline import device_latency

    # --- leg a: trace duty cycles (deterministic) --------------------------
    period, n_r = 8.0, 80
    ids = jnp.arange(64, dtype=jnp.int32)
    duty_ok = True
    for trace, rate in (("square", 0.25), ("square", 0.75),
                        ("diurnal", 0.5)):
        s = scn.Scenario(trace=trace, period=period, availability=rate,
                         seed=0)
        masks = np.stack([np.asarray(scn.availability_mask(
            s, 0, rate, jnp.int32(r), ids)) for r in range(n_r)])
        err = abs(float(masks.mean()) - rate)
        tol = 1.0 / period if trace == "square" else 0.06
        duty_ok = duty_ok and err <= tol
        emit(f"scenario/duty/{trace}_{rate}", 0.0, rate=rate,
             measured=round(float(masks.mean()), 4), err=round(err, 4),
             tol=tol)
    emit("scenario/claim_trace_duty_cycle", 0.0, holds=bool(duty_ok),
         period=period, rounds=n_r)

    # --- leg b: adaptive deadline quantile convergence (deterministic) -----
    cfg = get_arch("paper_lm")
    model = Model(cfg)
    clients = 8
    dcfg = FedDataConfig(vocab_size=cfg.vocab_size, num_clients=clients,
                         seq_len=48, batch_per_client=4, heterogeneity=2.0,
                         seed=0)

    def data_fn(r):
        return sample_round(dcfg, jax.random.fold_in(jax.random.PRNGKey(1),
                                                     r))

    n_ev = clients * (4 if SMOKE else max(8, rounds))
    fl_q = FLConfig(algorithm="fedavg", local_steps=2, local_lr=0.2,
                    uplink_compressor="qsgd8",
                    scenario_deadline_quantile=0.5)
    a = make_async_step(model, fl_q, clients, data_fn, buffer_size=clients,
                        latency_profile="constant", chunk=48)
    state = a.init_fn(jax.random.PRNGKey(0))
    state, ms = run_rounds(a.engine, state, data_fn, n_ev, chunk=16)
    q = np.asarray(ms["q_est"], np.float64)
    # constant profile: every completion takes exactly 1.0 virtual seconds
    q_err = abs(float(q[-1]) - 1.0)
    emit("scenario/claim_adaptive_deadline_converges", 0.0,
         holds=bool(q_err < 0.5), q_final=round(float(q[-1]), 3),
         true_latency=1.0, events=n_ev)

    # --- leg c: the async race under realistic dynamics (nightly) ----------
    base = dict(algorithm="fedavg", local_steps=2, local_lr=0.2,
                uplink_compressor="qsgd8")
    dyn_sync = dict(scenario_trace="diurnal", scenario_availability=0.7,
                    scenario_dropout=0.1, scenario_period=8.0)
    dyn_async = dict(scenario_dropout=0.1,
                     scenario_deadline_quantile=0.75)
    ev = eval_batch(dcfg, jax.random.PRNGKey(99), batch_size=8)

    def metrics_fn(state, m):
        return dict(m, eval_loss=model.loss(state.params, ev, chunk=48)[0])

    # sync leg: barrier per round under diurnal availability + dropout
    losses, bytes_cum, us = _fl_run(FLConfig(**base, **dyn_sync), rounds)
    resources = sample_round(dcfg, jax.random.PRNGKey(7))["resources"]
    t, sync_t = 0.0, []
    for r in range(rounds):
        lat = device_latency("heavy_tail", resources,
                             jax.random.fold_in(jax.random.PRNGKey(13), r))
        t += float(jnp.max(lat))
        sync_t.append(t)
    emit("scenario/sync_diurnal_dropout", us,
         loss_final=round(losses[-1], 4),
         mb=round(bytes_cum[-1] / 1e6, 2), vclock=round(sync_t[-1], 1))

    # async leg: FedBuff under dropout + adaptive deadline arming
    n_events = rounds * clients
    fl_a = FLConfig(**base, **dyn_async)
    a = make_async_step(model, fl_a, clients, data_fn, buffer_size=4,
                        staleness_alpha=0.5, latency_profile="heavy_tail",
                        chunk=48)
    state = a.init_fn(jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    state, ms = run_rounds(a.engine, state, data_fn, n_events, chunk=16,
                           metrics_fn=metrics_fn, eval_every=clients)
    jax.block_until_ready(ms["clock"])
    us = (time.perf_counter() - t0) / n_events * 1e6
    evl = np.asarray(ms["eval_loss"], np.float64)
    clock = np.asarray(ms["clock"], np.float64)
    keep = np.isfinite(evl)
    evl, clock = evl[keep], clock[keep]
    emit("scenario/fedbuff_dropout_adaptive", us,
         loss_final=round(float(evl[-1]), 4),
         vclock=round(float(clock[-1]), 1),
         q_final=round(float(np.asarray(ms["q_est"])[-1]), 2))

    # time-to-target on the shared bar (same construction as bench_async)
    target = max(losses[-1], float(evl[-1])) + 0.02
    s_idx = next((i for i, x in enumerate(losses) if x <= target), None)
    a_idx = next((i for i, x in enumerate(evl) if x <= target), None)
    t_sync = sync_t[s_idx] if s_idx is not None else float("inf")
    t_async = float(clock[a_idx]) if a_idx is not None else float("inf")
    emit("scenario/claim_fedbuff_beats_sync_under_dynamics", 0.0,
         holds=bool(t_async < t_sync), target=round(target, 3),
         fedbuff_vclock=round(t_async, 1), sync_vclock=round(t_sync, 1),
         note="diurnal+dropout-sync-vs-dropout+adaptive-fedbuff")


BENCHES = {
    "compression": bench_compression,
    "kernels": bench_kernels,
    "convergence": bench_convergence,
    "bytes_to_loss": bench_bytes_to_loss,
    "combined": bench_combined,
    "selection": bench_selection,
    "hierarchy": bench_hierarchy,
    "async": bench_async,
    "engine": bench_engine,
    "extensions": bench_extensions,
    "roofline": bench_roofline,
    "scale": bench_scale,
    "fused": bench_fused,
    "privacy": bench_privacy,
    "obs": bench_obs,
    "scenario": bench_scenario,
}


def _write_bench_json(path: str, args) -> None:
    """Per-PR perf trajectory record: git SHA, config hash, backend, and
    every emitted row (claim rows — the ``holds=`` ones — pulled out
    separately).  Committed as ``benchmarks/BENCH_<pr>.json``."""
    import dataclasses
    import hashlib
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sha = "unknown"
    try:
        sha = subprocess.check_output(
            ["git", "rev-parse", "HEAD"], cwd=root,
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        pass
    config_hash = hashlib.sha256(repr(
        (dataclasses.asdict(FLConfig()),
         dataclasses.asdict(get_arch("paper_lm")))).encode()).hexdigest()[:16]
    rows = []
    for raw in ROWS:
        name, us, derived = raw.split(",", 2)
        d = dict(kv.split("=", 1) for kv in derived.split(";") if "=" in kv)
        rows.append({"name": name, "us_per_call": float(us), "derived": d})
    payload = {
        "pr": 10,
        "git_sha": sha,
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "config_hash": config_hash,
        "args": {"only": args.only, "rounds": args.rounds,
                 "smoke": args.smoke},
        "claims": [r for r in rows if "holds" in r["derived"]],
        "rows": rows,
    }
    # the trajectory baseline is the COMMITTED benchmarks/ back-catalog, not
    # the --bench-json output directory (CI writes that to /tmp, which would
    # silently leave `prior` empty and skip the whole check)
    _check_trajectory(payload, os.path.dirname(os.path.abspath(__file__)))
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)
        fh.write("\n")
    print(f"wrote {path} ({len(rows)} rows, "
          f"{len(payload['claims'])} claims)", flush=True)


def _load_claims_registry():
    """Load benchmarks/claims.py by path (works however run.py was
    invoked — ``-m benchmarks.run`` or as a script); cached, since emit()
    consults it per claim row."""
    mod = sys.modules.get("_bench_claims")
    if mod is not None:
        return mod
    import importlib.util
    p = os.path.join(os.path.dirname(os.path.abspath(__file__)), "claims.py")
    spec = importlib.util.spec_from_file_location("_bench_claims", p)
    mod = importlib.util.module_from_spec(spec)
    sys.modules["_bench_claims"] = mod   # dataclasses resolves __module__
    spec.loader.exec_module(mod)
    return mod


def _check_trajectory(payload, bench_dir) -> None:
    """Per-PR claim trajectory, driven by the benchmarks/claims.py registry:

      * every emitted ``holds=`` row must be a registered Claim — an
        unregistered claim row fails the run, naming the file to fix;
      * every re-measured registered claim must report ``holds=True`` —
        registered claims are STANDING claims, so a False is a
        perf/correctness regression whether or not an older BENCH json
        re-measured it (this is what lets the nightly recheck gate claims
        first recorded in this PR's own BENCH_<pr>.json);
      * ``bench_dir`` is the committed benchmarks/ directory — the
        BENCH_<k>.json back-catalog (k <= this PR) names, per failed
        claim, the record it last held in.

    Claims not re-measured (different --only) are skipped with a note."""
    import re
    registry = _load_claims_registry()
    missing = registry.unregistered(c["name"] for c in payload["claims"])
    if missing:
        raise SystemExit(
            f"unregistered claim row(s) {missing}: every holds= row needs "
            f"a Claim entry (id + reproduce + tolerance) in "
            f"benchmarks/claims.py")
    prior = sorted(
        (int(m.group(1)), p) for p in glob.glob(
            os.path.join(bench_dir, "BENCH_*.json"))
        if (m := re.search(r"BENCH_(\d+)\.json$", p))
        and int(m.group(1)) <= payload["pr"])
    # union of the committed back-catalog, newest record per claim wins —
    # claims last measured two PRs ago still gate the recheck
    prev_claims, src = {}, {}
    for k, p in prior:
        with open(p) as fh:
            prev = json.load(fh)
        for c in prev.get("claims", []):
            prev_claims[c["name"]] = c
            src[c["name"]] = os.path.basename(p)
    names = ", ".join(os.path.basename(p) for _, p in prior) or "(none)"
    now = {c["name"]: c["derived"].get("holds") for c in payload["claims"]}
    skipped = [n for n, c in prev_claims.items()
               if str(c["derived"].get("holds")) == "True" and n not in now]
    if skipped:
        print(f"trajectory: {len(skipped)} prior claim(s) not re-measured "
              f"this run (--only): {skipped}", flush=True)
    failed = [n for n, h in now.items() if str(h) != "True"]
    if failed:
        def _where(n):
            return (f"held in {src[n]}" if str(
                prev_claims.get(n, {}).get("derived", {}).get("holds"))
                == "True" else "no prior holds=True record")
        detail = "\n".join(
            f"  {n} ({_where(n)}; tolerance: {cl.tolerance}; "
            f"reproduce: {cl.reproduce})"
            if (cl := registry.lookup(n)) else f"  {n} ({_where(n)})"
            for n in failed)
        raise SystemExit(
            f"claim regression vs {names}: "
            f"registered claims measured holds=False:\n{detail}")
    print(f"trajectory vs {names}: {len(now)} re-measured claim(s) hold, "
          f"no regression", flush=True)


def main() -> None:
    global SMOKE
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names "
                         f"(have: {','.join(BENCHES)})")
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI legs (e.g. scale: 100k clients, 2 rounds)")
    ap.add_argument("--bench-json", default=None, metavar="PATH",
                    help="also write the emitted rows + git SHA / config "
                         "hash / backend as a per-PR JSON record")
    args = ap.parse_args()
    SMOKE = args.smoke
    only = None
    if args.only:
        only = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in only if s not in BENCHES]
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; have {list(BENCHES)}")
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if only and name not in only:
            continue
        fn(args.rounds)
    if args.bench_json:
        _write_bench_json(args.bench_json, args)


if __name__ == '__main__':
    main()
