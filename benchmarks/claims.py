"""Machine-readable registry of every measured claim this repo stands on.

One ``Claim`` per ``holds=`` row the benchmark harness emits (or per
parametrised family of rows), carrying the exact reproduce command and the
tolerance the ``holds`` predicate grants.  Three consumers:

  * ``benchmarks/run.py::_check_trajectory`` — refuses to write a
    ``BENCH_<pr>.json`` whose claim rows are not registered here, and
    prints each flipped claim's reproduce command when a previously-held
    claim regresses;
  * ``tests/test_claims_registry.py`` — asserts every claim id quoted in
    EXPERIMENTS.md exists here, so the prose and the registry cannot
    drift apart;
  * the ``claims-recheck`` CI job — re-runs the ``smoke``-tier suites and
    fails loudly on any holds flip (the nightly-style standing check).

Pure stdlib on purpose: loaded by path from run.py and from tests without
importing jax.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Claim:
    """One standing measured claim.

    ``id`` is the emitted row name.  A ``family=True`` claim covers every
    row named ``<id>/<param>`` (e.g. ``fused/claim_ledger_eq_hlo/ternary``).
    ``tolerance`` states, in words, exactly how much slack the ``holds``
    predicate grants — "exact (bitwise)" means none.  ``smoke=True`` means
    the predicate is deterministic enough to re-check under ``--smoke``
    (tiny CI legs); seed-noisy convergence races are ``smoke=False`` and
    only re-measured on full runs.
    """
    id: str
    suite: str
    reproduce: str
    tolerance: str
    description: str
    smoke: bool = True
    family: bool = False


def _cmd(suite: str) -> str:
    return f"PYTHONPATH=src python -m benchmarks.run --only {suite}"


REGISTRY: tuple[Claim, ...] = (
    # --- convergence (§III.B.1) -------------------------------------------
    Claim("convergence/claim_scaffold_fixes_drift_quadratic", "convergence",
          _cmd("convergence"),
          "scaffold_err < 0.01 x fedavg_bias",
          "On the heterogeneous-quadratic drift construction of [46], "
          "SCAFFOLD lands >=100x closer to the true optimum than FedAvg."),
    # --- selection (§III.B.2) ---------------------------------------------
    # nightly tier (smoke=False): the bench pins every rng (data seed 0-2,
    # init PRNGKey(seed), selection keys from the engine's fold_in
    # schedule), so run-to-run variance comes only from averaging 3 fixed
    # seeds — the +0.02 band absorbs the residual spread at 25 rounds
    Claim("selection/claim_poc_beats_random", "selection",
          _cmd("selection"),
          "mean final loss over 3 fixed seeds (0,1,2): "
          "poc <= random + 0.02",
          "Power-of-Choice matches or beats random client selection at the "
          "same cohort size.", smoke=False),
    # --- async (§III.B / DESIGN.md §7-8) ----------------------------------
    # nightly tier: seeds pinned (init PRNGKey(0), data PRNGKey(1),
    # latency PRNGKey(13) per round), so the virtual-clock race is
    # deterministic per machine; the margin-free strict inequality held
    # at ~2.4x in the recorded runs — a flip is a real regression
    Claim("async/claim_fedbuff_beats_sync_time_to_target", "async",
          _cmd("async"),
          "best count-flush K strictly faster (virtual clock) than sync; "
          "fixed seeds, measured margin ~2.4x at 25 rounds",
          "FedBuff reaches the shared target loss in less virtual "
          "wall-clock than sync FedAvg under heavy-tail stragglers.",
          smoke=False),
    Claim("async/claim_deadline_flush_vs_k_flush", "async",
          _cmd("async"),
          "deadline-flush vclock <= 1.25 x best count-flush K "
          "(fixed seeds; the 25% band absorbs flush-phase alignment)",
          "Adaptive (deadline) buffer flushing is competitive with the "
          "best hand-tuned buffer size K.", smoke=False),
    # --- scenario pack (DESIGN.md §13) ------------------------------------
    Claim("scenario/claim_trace_duty_cycle", "scenario",
          _cmd("scenario") + "   # CI: --smoke",
          "square: |duty - rate| <= 1/period (exact windows); "
          "diurnal: |mean duty - rate| <= 0.06 over 80 rounds x 64 clients",
          "The availability traces hit their configured duty cycle: "
          "square exactly per period, diurnal in time-average (the "
          "sinusoid amplitude clamp keeps the mean at the rate)."),
    Claim("scenario/claim_adaptive_deadline_converges", "scenario",
          _cmd("scenario") + "   # CI: --smoke",
          "|q_est - 1.0| < 0.5 on the constant-latency profile "
          "(oscillation ~ eta * q = 5%)",
          "The Robbins-Monro completion-time quantile tracker the async "
          "engine arms deadlines from converges to the observed "
          "completion time."),
    Claim("scenario/claim_fedbuff_beats_sync_under_dynamics", "scenario",
          _cmd("scenario"),
          "fedbuff(dropout+adaptive) strictly faster (virtual clock) than "
          "sync(diurnal 0.7 + dropout); fixed seeds",
          "The async headline claim survives realistic client dynamics: "
          "FedBuff still beats sync FedAvg to the shared target when "
          "both run under the scenario pack's dynamics.", smoke=False),
    # --- scale (DESIGN.md §9) ---------------------------------------------
    Claim("scale/claim_memory_flat_in_population", "scale",
          _cmd("scale") + "   # CI: --smoke",
          "exact (store bytes identical at 100k and 1M clients)",
          "ResidualStore memory is bounded by capacity, not population."),
    Claim("scale/claim_degenerate_bitexact", "scale",
          _cmd("scale") + "   # CI: --smoke",
          "exact (bitwise params + comm_state)",
          "With cohort == n_clients <= capacity the population path "
          "reproduces the dense sim and async engines bit-for-bit."),
    # --- fused wire formats (DESIGN.md §10) -------------------------------
    Claim("fused/claim_ledger_eq_hlo", "fused",
          _cmd("fused") + "   # CI: --smoke",
          "exact (ledger bytes == summed all-gather bytes in compiled HLO)",
          "The packed uint8 wire the ledger bills is byte-identical to "
          "what the compiled 8-device star program all-gathers.",
          family=True),
    Claim("fused/claim_packed_shrinks_wire", "fused",
          _cmd("fused") + "   # CI: --smoke",
          "strict inequality per spec (packed AG bytes < staged AG bytes)",
          "Packed wire formats strictly shrink the collective vs the "
          "staged wire for every packable spec.", family=True),
    Claim("fused/claim_encode_no_worse", "fused",
          _cmd("fused"),
          "packed encode <= 1.10 x staged encode, aggregate wall-clock",
          "Bit-packing on the wire does not slow encode down "
          "(the TopkRewriter order-statistic guard).", smoke=False),
    # --- privacy (DESIGN.md §11) ------------------------------------------
    Claim("privacy/claim_masked_bitexact", "privacy",
          _cmd("privacy") + "   # CI: --smoke; harness: "
          "PYTHONPATH=src python -m pytest tests/test_secure_agg.py",
          "exact (bitwise params, ctx-stripped comm_state, wire bytes)",
          "A secagg-masked training run equals the unmasked run "
          "bit-for-bit after mask removal: masks cancel in integer "
          "arithmetic, so privacy costs zero model fidelity."),
    Claim("privacy/claim_masking_zero_wire_cost", "privacy",
          _cmd("privacy") + "   # CI: --smoke",
          "exact (ledger wire bits identical, +16 payload bytes/leaf ctx)",
          "Masking is free on the billed wire: masked integer codes ship "
          "in the same dtype and width as clear codes."),
    Claim("privacy/claim_dp_pareto", "privacy",
          _cmd("privacy") + "   # CI: --smoke",
          "per-client eps strictly decreasing in sigma; wire bytes "
          "identical across the sweep; loss reported, not gated",
          "The dpnoise sweep traces the privacy/bytes/accuracy Pareto: "
          "stronger noise buys a lower per-client (eps, delta) guarantee "
          "at identical wire cost, paying only in loss."),
    # --- observability (DESIGN.md §12) ------------------------------------
    Claim("obs/claim_stage_sum_exact", "obs",
          _cmd("obs") + "   # CI: --smoke; harness: "
          "PYTHONPATH=src python -m pytest tests/test_obs.py",
          "exact (f32 residual identity) + f64 rtol 1e-6 direct sum",
          "The flight recorder's per-stage byte slots reconstruct the "
          "CommLedger wire totals bit-exactly in f32: attribution adds "
          "information, never a second bookkeeping that can drift."),
    Claim("obs/claim_telemetry_overhead", "obs",
          _cmd("obs"),
          "traced wall-clock <= 1.05 x untraced (full run; smoke only "
          "checks the trace validates and the report renders)",
          "Recording RoundStats in-graph and spilling the JSONL trace "
          "host-side costs <= 5% wall-clock on paper_lm: observability "
          "is cheap enough to leave on.", smoke=False),
)

_BY_ID = {c.id: c for c in REGISTRY}


def lookup(name: str) -> Claim | None:
    """Resolve an emitted claim-row name to its registered Claim — exact
    id match, or the family prefix for ``<id>/<param>`` rows."""
    if name in _BY_ID:
        return _BY_ID[name]
    for c in REGISTRY:
        if c.family and name.startswith(c.id + "/"):
            return c
    return None


def unregistered(names) -> list[str]:
    """The subset of emitted claim-row names with no registered Claim."""
    return [n for n in names if lookup(n) is None]


def by_suite(suite: str) -> list[Claim]:
    return [c for c in REGISTRY if c.suite == suite]


def smoke_suites() -> list[str]:
    """Suites with at least one smoke-checkable claim — the claims-recheck
    CI job re-runs exactly these.  A suite may also contain seed-noisy
    ``smoke=False`` claims; under ``--smoke`` the harness drops their
    ``holds=`` verdicts at emit time (benchmarks/run.py), so rechecking
    such a suite gates only on its deterministic claims."""
    return sorted({c.suite for c in REGISTRY if c.smoke})


def nightly_suites() -> list[str]:
    """Suites with at least one ``smoke=False`` claim — the budgeted
    ``claims-nightly`` CI job re-runs exactly these WITHOUT ``--smoke``
    (full rounds), so the seed-pinned convergence races get their
    ``holds=`` verdicts re-measured on a schedule instead of per-push."""
    return sorted({c.suite for c in REGISTRY if not c.smoke})
