"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh pod1]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


import re

_BASE = re.compile(r"__(" + "|".join(SHAPE_ORDER) + r")\.json$")


def load(mesh="pod1", fl="baseline", base="experiments/dryrun"):
    out = {}
    for f in glob.glob(os.path.join(base, mesh, fl, "*.json")):
        if not _BASE.search(os.path.basename(f)):
            continue   # skip §Perf-tagged experiment records
        with open(f) as fh:
            r = json.load(fh)
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_sec(s):
    if s >= 100:
        return f"{s:,.0f}"
    if s >= 1:
        return f"{s:.2f}"
    return f"{s:.2e}"


def table(recs, full=True):
    rows = []
    hdr = ("| arch | shape | ok | compute s | memory s | collective s | "
           "dominant | MODEL/HLO flops | peak GB/dev | collectives |")
    sep = "|" + "---|" * 10
    rows += [hdr, sep]
    archs = sorted({a for a, _ in recs})
    for a in archs:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                continue
            if not r.get("ok"):
                rows.append(f"| {a} | {s} | FAIL | | | | | | | "
                            f"{r.get('error','')[:60]} |")
                continue
            t = r["roofline"]
            cbt = r.get("coll_by_type", {})
            cstr = " ".join(f"{k.split('-')[-1][:4]}:{v/1e9:.1f}G"
                            for k, v in sorted(cbt.items()))
            rows.append(
                f"| {a} | {s} | ok | {fmt_sec(t['compute_s'])} | "
                f"{fmt_sec(t['memory_s'])} | {fmt_sec(t['collective_s'])} | "
                f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
                f"{r['memory']['peak_gb']:.1f} | {cstr} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--fl", default="baseline")
    args = ap.parse_args()
    recs = load(args.mesh, args.fl)
    print(f"### {args.mesh} / {args.fl} ({len(recs)} records)\n")
    print(table(recs))
    # worst roofline fraction (compute/total) and most collective-bound
    ok = [r for r in recs.values() if r.get("ok")]
    def frac(r):
        t = r["roofline"]
        tot = t["compute_s"] + t["memory_s"] + t["collective_s"]
        return t["compute_s"] / tot if tot else 0
    worst = min(ok, key=frac)
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"]
               / max(sum(r["roofline"].values()), 1e-9))
    print(f"\nworst compute fraction: {worst['arch']}/{worst['shape']} "
          f"({frac(worst):.3f})")
    print(f"most collective-bound: {coll['arch']}/{coll['shape']} "
          f"(coll {coll['roofline']['collective_s']:.1f}s)")


if __name__ == "__main__":
    main()
